"""Async futures executor: event-driven dispatch vs the wave barrier.

The contract under test (see ``repro.runtime.executor``): the async
runner may complete fronts in any order the tree admits — stragglers
stall only their ancestors — yet the factors stay bit-identical to the
wave path, precedence is never violated, and freed-buffer accounting
keeps the measured peak within the wave path's when capped.
"""
import math
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.distributed.device_groups import BuddyAllocator
from repro.runtime.executor import MODES, PlanExecutor
from repro.runtime.straggler import FrontDelays
from repro.sparse import (
    analyze,
    grid_laplacian_2d,
    make_plan,
    nested_dissection_2d,
    permute_symmetric,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="module")
def problem():
    a = grid_laplacian_2d(9)
    ap = permute_symmetric(a, nested_dissection_2d(9))
    symb = analyze(ap, relax=1)
    plan = make_plan(symb.task_tree(), 8, alpha=0.9)
    return ap, symb, plan


def _run(problem, mode, **kw):
    ap, symb, plan = problem
    return PlanExecutor(symb, plan, mode=mode, **kw).run(ap, warmup=False)


# ----------------------------------------------------------------------
# BuddyAllocator: incremental power-of-two group carving
# ----------------------------------------------------------------------
def test_buddy_alloc_pow2_aligned():
    alloc = BuddyAllocator(8)
    g4 = alloc.alloc(4)
    g2 = alloc.alloc(2)
    g1 = alloc.alloc(3)  # 3 floors to 2, halves to fit the free single
    for g in (g4, g2, g1):
        assert g is not None
        assert g.size & (g.size - 1) == 0
        assert g.offset % g.size == 0
    assert g4.size == 4 and g2.size == 2
    assert alloc.n_free == 8 - g4.size - g2.size - g1.size


def test_buddy_exhaustion_and_free():
    alloc = BuddyAllocator(4)
    gs = [alloc.alloc(1) for _ in range(4)]
    assert all(g is not None for g in gs)
    assert alloc.n_free == 0
    assert alloc.alloc(1) is None  # full: caller must wait for a free
    alloc.free(gs[1])
    assert alloc.n_free == 1
    g = alloc.alloc(4)  # only one device free: degrades, never None
    assert g is not None and g.size == 1 and g.offset == gs[1].offset


def test_buddy_double_free_asserts():
    alloc = BuddyAllocator(2)
    g = alloc.alloc(2)
    alloc.free(g)
    with pytest.raises(AssertionError):
        alloc.free(g)


# ----------------------------------------------------------------------
# FrontDelays: the deterministic straggler injection
# ----------------------------------------------------------------------
def test_front_delays_random_seeded():
    d1 = FrontDelays.random(range(40), 5, 0.25, seed=3)
    d2 = FrontDelays.random(range(40), 5, 0.25, seed=3)
    assert d1.delays == d2.delays  # same seed, same stragglers
    assert len(d1.delays) == 5
    assert d1.total() == pytest.approx(1.25)
    hit = next(iter(d1.delays))
    assert d1(hit) == 0.25
    miss = next(s for s in range(40) if s not in d1.delays)
    assert d1(miss) == 0.0


def test_bad_mode_rejected(problem):
    ap, symb, plan = problem
    with pytest.raises(ValueError):
        PlanExecutor(symb, plan, mode="eager")
    assert MODES == ("async", "waves")


# ----------------------------------------------------------------------
# Bit-identical factors + per-front observables
# ----------------------------------------------------------------------
def test_async_bit_identical_to_waves(problem):
    ap, symb, plan = problem
    fw, rw = _run(problem, "waves")
    fa, ra = _run(problem, "async")
    for pw, pa in zip(fw.panels, fa.panels):
        np.testing.assert_array_equal(pw, pa)
    dense = ap.toarray()
    l = fa.to_dense_l()
    assert np.abs(l @ l.T - dense).max() / np.abs(dense).max() < 1e-5
    assert rw.mode == "waves" and ra.mode == "async"

    # async records per-front readiness; the wave path has no such instant
    assert all(not math.isnan(e.t_ready) for e in ra.trace)
    assert all(not math.isnan(e.t_submit) for e in ra.trace)
    assert all(math.isnan(e.t_ready) for e in rw.trace)
    assert ra.mean_ready_latency() is not None
    assert rw.mean_ready_latency() is None
    # submit happens at/after ready, dispatch at/after submit
    for e in ra.trace:
        assert e.t_submit >= e.t_ready - 1e-9
        assert e.dispatch_latency >= -1e-9
        assert e.ready_latency >= -1e-9


def test_async_tree_precedence(problem):
    ap, symb, plan = problem
    _, ra = _run(problem, "async")
    ev = {e.front: e for e in ra.trace}
    assert sorted(ev) == list(range(symb.n_supernodes))
    for s, sn in enumerate(symb.supernodes):
        if sn.parent >= 0:
            # a parent's dispatch starts only after the child landed
            assert ev[sn.parent].t_start >= ev[s].t_end - 1e-9
            # and its recorded ready instant is the last child completion
            assert ev[sn.parent].t_ready >= ev[s].t_end - 1e-9


@pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="overtaking needs a second device group (one device means the "
    "straggler holds the whole mesh); CI's forged 8-device job runs this",
)
def test_async_out_of_order_completion(problem):
    """A straggling leaf must not stall unrelated fronts (no barrier)."""
    ap, symb, plan = problem
    # delay the first leaf; everything outside its ancestor chain should
    # overtake it
    leaf = next(
        s for s in range(symb.n_supernodes) if not any(
            symb.supernodes[c].parent == s for c in range(symb.n_supernodes)
        )
    )
    delays = FrontDelays(delays={leaf: 0.5})
    # max_batch=1 keeps the straggler out of its siblings' dispatches
    # (coalescing would make the whole shape class as slow as its slowest
    # member, which is the point of batching — but not of this test)
    fw, rw = _run(problem, "waves", delay_fn=delays, max_batch=1)
    fa, ra = _run(problem, "async", delay_fn=delays, max_batch=1)
    for pw, pa in zip(fw.panels, fa.panels):
        np.testing.assert_array_equal(pw, pa)

    ancestors = {leaf}
    p = symb.supernodes[leaf].parent
    while p >= 0:
        ancestors.add(p)
        p = symb.supernodes[p].parent
    ev = {e.front: e for e in ra.trace}
    overtakers = [
        s
        for s in range(symb.n_supernodes)
        if s not in ancestors and ev[s].t_end < ev[leaf].t_end
    ]
    assert overtakers, "no front overtook the injected straggler"
    # the barrier pays the stall on the whole mesh; the futures runner
    # hides it behind independent work
    assert ra.measured_makespan < rw.measured_makespan


def test_async_peak_capped_by_wave_peak(problem):
    """Freed-buffer accounting: capped async stays within the wave peak."""
    _, rw = _run(problem, "waves")
    _, ra = _run(
        problem, "async", memory_cap_bytes=rw.measured_peak_bytes
    )
    assert ra.measured_peak_bytes <= rw.measured_peak_bytes
    assert ra.measured_peak_bytes > 0


def test_async_chrome_trace_export(problem):
    _, ra = _run(problem, "async")
    _, rw = _run(problem, "waves")
    evs = ra.to_trace()
    assert evs and all(e["ph"] == "X" for e in evs)
    assert all(e["dur"] > 0 for e in evs)
    assert all("ready_latency_s" in e["args"] for e in evs)
    assert all("dispatch_latency_s" in e["args"] for e in evs)
    assert {e["cat"] for e in evs} == {"async"}
    # the wave trace has no readiness observables to export
    wevs = rw.to_trace()
    assert all("ready_latency_s" not in e["args"] for e in wevs)


# ----------------------------------------------------------------------
# The public surfaces: Session.execute(mode=) and execute_online
# ----------------------------------------------------------------------
def test_session_execute_mode():
    from repro.api import DeviceMesh, Problem, Session

    g = 9
    a = grid_laplacian_2d(g)
    prob = Problem.from_matrix(
        a, 0.9, ordering=nested_dissection_2d(g), relax=1
    )
    sess = Session(DeviceMesh(plan_devices=8)).load(prob).plan("greedy")
    rep_w = sess.execute(warmup=False, mode="waves")
    rep_a = sess.execute(warmup=False)  # async is the default
    assert rep_w.detail.mode == "waves"
    assert rep_a.detail.mode == "async"
    np.testing.assert_array_equal(
        rep_w.artifact.to_dense_l(), rep_a.artifact.to_dense_l()
    )
    # no ready-latency samples under waves: the key is absent (metrics
    # never carry None/NaN — the obs layer's null-free contract)
    assert "mean_ready_latency_s" not in rep_w.metrics
    assert rep_a.metrics["mean_ready_latency_s"] >= 0.0


def test_execute_online_async():
    from repro.online.replay import execute_online

    g = 9
    a = grid_laplacian_2d(g)
    ap = permute_symmetric(a, nested_dissection_2d(g))
    symb = analyze(ap, relax=1)
    fact, exec_rep, online_rep = execute_online(
        ap, symb, 8, 0.9, warmup=False
    )
    assert exec_rep.mode == "async"
    dense = ap.toarray()
    l = fact.to_dense_l()
    assert np.abs(l @ l.T - dense).max() / np.abs(dense).max() < 1e-5


# ----------------------------------------------------------------------
# Bit-identity matrix: {async, waves, sequential} × {optimized, unopt}
# ----------------------------------------------------------------------
@pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="the matrix needs real device groups to be a meaningful cross-"
    "check; CI's forged 8-device job runs this",
)
def test_bit_identity_matrix_optimized(problem):
    """Every runner × every tree rewrite lands the same factor bits.

    The amalgamated plan schedules fused groups, yet each member front
    still assembles (extend-add in tree order) and factors at its own
    padded shape class — so all six legs must agree bit-for-bit.  The
    sequential leg routes ``factorize`` through the *executor's* kernel
    path (pad → batched vmap factor → extract), not the jnp reference
    kernel, so it is the same arithmetic by construction.
    """
    import jax.numpy as jnp

    from repro.api import DeviceMesh, Problem, Session
    from repro.kernels.frontal_cholesky import VMEM_FRONT_MAX
    from repro.kernels.ops import (
        batched_front_factor,
        extract_panel_schur,
        pad_front_np,
        padded_shape,
        partial_cholesky,
    )
    from repro.sparse import factorize

    ap, symb, plan = problem
    interpret = jax.default_backend() != "tpu"

    def kernel_factor(f, nb):
        # the executor's small-front path, one-lane batch
        fh = np.asarray(f)
        mp, nbp = padded_shape(fh.shape[0], nb)
        if mp > VMEM_FRONT_MAX:
            return partial_cholesky(f, nb, interpret=interpret)
        batch = pad_front_np(fh, nb, fh.dtype)[None]
        out = np.asarray(
            jax.block_until_ready(
                batched_front_factor(jnp.asarray(batch), nbp, interpret)
            )
        )
        return extract_panel_schur(out[0], fh.shape[0], nb)

    legs = {"sequential/unopt": factorize(ap, symb, factor_fn=kernel_factor)}
    for mode in MODES:
        legs[f"{mode}/unopt"], _ = _run(problem, mode)

    prob = Problem.from_symbolic(symb, 0.9, matrix=ap)
    sess = Session(DeviceMesh()).load(prob).optimize(max_front=64)
    assert sess.problem.n < prob.n, "amalgamation found nothing to fuse"
    sess.plan("greedy")
    for mode in MODES:
        legs[f"{mode}/opt"] = sess.execute(
            warmup=False, mode=mode
        ).artifact

    ref_name, ref = next(iter(legs.items()))
    for name, fact in legs.items():
        for s, (pr, pf) in enumerate(zip(ref.panels, fact.panels)):
            np.testing.assert_array_equal(
                pr, pf, err_msg=f"panel {s}: {name} != {ref_name}"
            )


@pytest.mark.slow
def test_async_beats_waves_forged_mesh():
    """The tentpole A/B on a forged 8-device mesh (subprocess owns the
    XLA flag): with injected stragglers the futures runner must beat the
    barrier, bit-identically, within the wave path's memory peak."""
    code = """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
from repro.runtime.executor import PlanExecutor
from repro.runtime.straggler import FrontDelays
from repro.sparse import analyze, grid_laplacian_2d, make_plan, \
    nested_dissection_2d, permute_symmetric

assert jax.device_count() == 8
a = grid_laplacian_2d(11)
ap = permute_symmetric(a, nested_dissection_2d(11))
symb = analyze(ap, relax=1)
plan = make_plan(symb.task_tree(), 8, alpha=0.9)
delays = FrontDelays.random(range(symb.n_supernodes), 4, 0.2, seed=1)
fw, rw = PlanExecutor(symb, plan, mode="waves", delay_fn=delays).run(ap)
fa, ra = PlanExecutor(
    symb, plan, mode="async", delay_fn=delays,
    memory_cap_bytes=rw.measured_peak_bytes,
).run(ap)
for pw, pa in zip(fw.panels, fa.panels):
    np.testing.assert_array_equal(pw, pa)
assert ra.measured_peak_bytes <= rw.measured_peak_bytes
speedup = rw.measured_makespan / ra.measured_makespan
assert speedup > 1.0, (rw.measured_makespan, ra.measured_makespan)
print("ASYNC_AB_OK", round(speedup, 3))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=env,
        timeout=420,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "ASYNC_AB_OK" in out.stdout
