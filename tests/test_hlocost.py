"""The loop-aware HLO cost model — deterministic unit checks on handwritten
HLO text (flop counting, trip-count multiplication, collective ring costs,
slice-aware fusion reads)."""
import pytest

from repro.launch.hlocost import analyze

HLO = """
HloModule test

%fused_slice (param_0.1: f32[8,128,64], param_1.1: s32[]) -> f32[128,64] {
  %param_0.1 = f32[8,128,64]{2,1,0} parameter(0)
  %param_1.1 = s32[] parameter(1)
  %c0 = s32[] constant(0)
  %dynamic-slice.1 = f32[1,128,64]{2,1,0} dynamic-slice(%param_0.1, %param_1.1, %c0, %c0), dynamic_slice_sizes={1,128,64}
  ROOT %bitcast.1 = f32[128,64]{2,1,0} bitcast(%dynamic-slice.1)
}

%body (param: (s32[], f32[64,64], f32[8,128,64])) -> (s32[], f32[64,64], f32[8,128,64]) {
  %param = (s32[], f32[64,64], f32[8,128,64]) parameter(0)
  %gte.0 = s32[] get-tuple-element(%param), index=0
  %gte.1 = f32[64,64]{1,0} get-tuple-element(%param), index=1
  %gte.2 = f32[8,128,64]{2,1,0} get-tuple-element(%param), index=2
  %w = f32[128,64]{2,1,0} fusion(%gte.2, %gte.0), kind=kLoop, calls=%fused_slice
  %dot.1 = f32[64,64]{1,0} dot(%gte.1, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[64,64]{1,0} all-reduce(%dot.1), replica_groups=[16,32]<=[512] to_apply=%add_comp
  %c1 = s32[] constant(1)
  %next = s32[] add(%gte.0, %c1)
  ROOT %tuple.1 = (s32[], f32[64,64], f32[8,128,64]) tuple(%next, %ar, %gte.2)
}

%cond (param.1: (s32[], f32[64,64], f32[8,128,64])) -> pred[] {
  %param.1 = (s32[], f32[64,64], f32[8,128,64]) parameter(0)
  %gte.3 = s32[] get-tuple-element(%param.1), index=0
  %c8 = s32[] constant(8)
  ROOT %lt = pred[] compare(%gte.3, %c8), direction=LT
}

%add_comp (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (p0: f32[64,64], p1: f32[8,128,64]) -> f32[64,64] {
  %p0 = f32[64,64]{1,0} parameter(0)
  %p1 = f32[8,128,64]{2,1,0} parameter(1)
  %c0.1 = s32[] constant(0)
  %t = (s32[], f32[64,64], f32[8,128,64]) tuple(%c0.1, %p0, %p1)
  %loop = (s32[], f32[64,64], f32[8,128,64]) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"8"}}
  ROOT %out = f32[64,64]{1,0} get-tuple-element(%loop), index=1
}
"""


def test_flops_multiplied_by_trip_count():
    s = analyze(HLO)
    # dot: (64,64) result × contracted 64 × 2 flops × 8 trips
    assert s.flops == pytest.approx(2 * 64 * 64 * 64 * 8)


def test_collective_ring_model_and_trips():
    s = analyze(HLO)
    # all-reduce of 64·64·4 bytes over groups of 32: 2·s·(n−1)/n, ×8 trips
    expect = 2 * (64 * 64 * 4) * (31 / 32) * 8
    assert s.collective_bytes["all-reduce"] == pytest.approx(expect)


def test_fusion_reads_only_the_slice():
    s = analyze(HLO)
    # the fusion's big operand (8·128·64 f32) is consumed only by a
    # dynamic-slice: charged at the slice size, not the full stack.
    slice_bytes = 128 * 64 * 4
    full_stack = 8 * slice_bytes
    # fusion contributes (result + sliced operand) per trip; if the full
    # stack were charged, bytes would exceed this bound by ≥ 7·slice·8
    assert s.bytes < full_stack * 8  # loose upper guard
    assert s.unknown_trip_whiles == 0
