"""Property-based half of the amalgamation invariant suite.

Drives the same ``check_*`` helpers as ``tests/test_optimize.py`` over
hypothesis-generated random trees (shared "repro" profile from
conftest: no deadline, derandomized, CI-vs-local example budget).  The
seeded deterministic half lives in ``test_optimize.py`` so it runs even
without the hypothesis dev extra.
"""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st  # noqa: E402

from repro.sparse.optimize import optimize_problem  # noqa: E402

from test_optimize import (  # noqa: E402
    check_budget,
    check_conservation,
    check_partition,
    check_plans_valid,
    check_roundtrip,
    random_problem,
)


@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(2, 60),
    with_fp=st.booleans(),
)
def test_prop_partition_and_conservation(seed, n, with_fp):
    prob = random_problem(seed, n=n, with_fp=with_fp)
    opt = optimize_problem(prob)
    check_partition(prob, opt)
    check_conservation(prob, opt)
    check_roundtrip(opt)


@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 40))
def test_prop_plans_stay_valid(seed, n):
    opt = optimize_problem(random_problem(seed, n=n))
    check_plans_valid(opt)


@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(2, 40),
    slack=st.floats(1.0, 2.0),
)
def test_prop_budget_respected(seed, n, slack):
    prob = random_problem(seed, n=n)
    budget = prob.min_peak_memory() * slack
    opt = optimize_problem(prob, memory_budget=budget)
    check_partition(prob, opt)
    check_budget(prob, opt, budget)
