"""Model zoo: all 10 assigned architectures (reduced configs) — forward,
loss, prefill/decode consistency, GLA correctness, attention equivalence.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; skip if absent
from hypothesis import given, strategies as st

from repro.configs import ARCHS
from repro.models import (
    build_decode_fn,
    build_loss_fn,
    build_prefill_fn,
    forward,
    init_params,
    random_batch,
)
from repro.models.attention import blocked_attention
from repro.models.gla import gla_chunked, gla_decode_step

KEY = jax.random.PRNGKey(0)
REDUCED = {name: cfg.reduced() for name, cfg in ARCHS.items()}


# ----------------------------------------------------------------------
# smoke: one forward + loss per arch (deliverable f)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_forward_loss(name):
    cfg = REDUCED[name]
    params = init_params(cfg, KEY)
    batch = random_batch(cfg, 2, 16, KEY)
    logits, aux = forward(cfg, params, batch["tokens"], extra=batch,
                          remat=False, attn_block=8)
    t = batch["tokens"].shape[1]
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.padded_vocab()
    assert logits.shape[1] >= t
    assert np.isfinite(np.asarray(logits)).all()
    loss = build_loss_fn(cfg, remat=False, attn_block=8)(params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_decode_continuation(name):
    """prefill(T) then decode must equal the teacher-forced forward."""
    cfg = REDUCED[name]
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    params = init_params(cfg, KEY)
    t0, extra_steps = 12, 3
    batch = random_batch(cfg, 2, t0 + extra_steps, KEY)
    toks = batch["tokens"]
    extra = {
        k: (v[:, :t0] if k == "frames" else v)
        for k, v in batch.items()
        if k != "tokens"
    }
    from repro.models import decode as dec

    _, cache = dec.prefill(cfg, params, toks[:, :t0], extra=extra,
                           remat=False, attn_block=8,
                           cache_dtype=jnp.float32)

    def pad_seq(a):
        padw = [(0, 0)] * a.ndim
        padw[2] = (0, extra_steps)
        return jnp.pad(a, padw)

    for kk in ("k", "v", "ak", "av", "xk", "xv"):
        if kk in cache:
            cache[kk] = pad_seq(cache[kk])
    decf = build_decode_fn(cfg)
    for i in range(extra_steps):
        logits_dec, cache = decf(params, cache, toks[:, t0 + i : t0 + i + 1])
        ref = dict(batch)
        ref["tokens"] = toks[:, : t0 + i + 1]
        if "frames" in ref:
            ref["frames"] = batch["frames"][:, :t0]
        full, _ = forward(cfg, params, ref["tokens"], extra=ref,
                          remat=False, attn_block=8)
        err = np.abs(
            np.asarray(full[:, -1, :]) - np.asarray(logits_dec[:, 0, :])
        ).max()
        assert err < 2e-4, (name, i, err)


@pytest.mark.parametrize("name", ["qwen3-4b", "rwkv6-1.6b", "zamba2-2.7b"])
def test_remat_does_not_change_loss(name):
    cfg = REDUCED[name]
    params = init_params(cfg, KEY)
    batch = random_batch(cfg, 2, 16, KEY)
    l1 = build_loss_fn(cfg, remat=False, attn_block=8)(params, batch)
    l2 = build_loss_fn(cfg, remat=True, attn_block=8)(params, batch)
    assert float(l1) == pytest.approx(float(l2), rel=1e-6)


# ----------------------------------------------------------------------
# blocked attention == naive softmax attention
# ----------------------------------------------------------------------
@given(
    st.integers(1, 3),
    st.integers(2, 5),  # T multiplier of block
    st.integers(1, 4),
    st.sampled_from([4, 8]),
    st.booleans(),
)
def test_blocked_attention_matches_naive(b, tm, h, dh, causal):
    block = 8
    t = tm * block - 3  # exercise padding
    key = jax.random.PRNGKey(b * 100 + tm * 10 + h)
    q, k, v = (
        jax.random.normal(kk, (b, t, h, dh))
        for kk in jax.random.split(key, 3)
    )
    out = blocked_attention(q, k, v, causal=causal, block=block)
    # naive reference
    scale = dh**-0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q * scale, k)
    if causal:
        mask = jnp.tril(jnp.ones((t, t), bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    ref = jnp.einsum("bhqk,bkhd->bqhd", w, v)
    assert np.abs(np.asarray(out) - np.asarray(ref)).max() < 1e-4


def test_blocked_attention_sliding_window():
    b, t, h, dh, w = 1, 32, 2, 8, 4
    key = jax.random.PRNGKey(7)
    q, k, v = (jax.random.normal(kk, (b, t, h, dh)) for kk in jax.random.split(key, 3))
    out = blocked_attention(q, k, v, causal=True, window=w, block=8)
    scale = dh**-0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q * scale, k)
    qi = jnp.arange(t)[:, None]
    ki = jnp.arange(t)[None, :]
    mask = (ki <= qi) & (ki > qi - w)
    logits = jnp.where(mask[None, None], logits, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(logits, -1), v)
    assert np.abs(np.asarray(out) - np.asarray(ref)).max() < 1e-4


# ----------------------------------------------------------------------
# GLA: chunked == sequential recurrence; decode step == one more token
# ----------------------------------------------------------------------
def _gla_naive(q, k, v, g, u=None, mode="post"):
    b, t, h, dk = q.shape
    dv = v.shape[-1]
    s = np.zeros((b, h, dk, dv))
    outs = []
    qf, kf, vf, gf = (np.asarray(x, np.float64) for x in (q, k, v, g))
    for i in range(t):
        s_new = s * np.exp(gf[:, i])[..., None] + np.einsum(
            "bhk,bhv->bhkv", kf[:, i], vf[:, i]
        )
        if mode == "post":
            o = np.einsum("bhk,bhkv->bhv", qf[:, i], s_new)
        else:
            o = np.einsum("bhk,bhkv->bhv", qf[:, i], s)
            uu = np.asarray(u, np.float64) if u is not None else 1.0
            o = o + np.einsum(
                "bhk,bhk,bhv->bhv", qf[:, i] * uu, kf[:, i], vf[:, i]
            )
        outs.append(o)
        s = s_new
    return np.stack(outs, axis=1), s


@given(
    st.integers(1, 2),
    st.sampled_from([7, 8, 16, 19]),
    st.integers(1, 3),
    st.sampled_from([4, 8]),
    st.sampled_from(["post", "pre"]),
    st.sampled_from([4, 8]),
)
def test_gla_chunked_matches_recurrence(b, t, h, dk, mode, chunk):
    key = jax.random.PRNGKey(b * 1000 + t * 10 + h)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, t, h, dk))
    k = jax.random.normal(ks[1], (b, t, h, dk))
    v = jax.random.normal(ks[2], (b, t, h, dk))
    g = -jnp.exp(jax.random.normal(ks[3], (b, t, h, dk)) * 0.5)
    u = jax.random.normal(ks[4], (h, dk)) if mode == "pre" else None
    out, s = gla_chunked(q, k, v, g, u=u, mode=mode, chunk=chunk)
    ref, s_ref = _gla_naive(q, k, v, g, u=u, mode=mode)
    assert np.abs(np.asarray(out) - ref).max() < 1e-4
    assert np.abs(np.asarray(s) - s_ref).max() < 1e-4


def test_gla_decode_step_continues_state():
    b, t, h, dk = 1, 9, 2, 4
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, t + 1, h, dk))
    k = jax.random.normal(ks[1], (b, t + 1, h, dk))
    v = jax.random.normal(ks[2], (b, t + 1, h, dk))
    g = -jnp.exp(jax.random.normal(ks[3], (b, t + 1, h, dk)) * 0.3)
    _, s = gla_chunked(q[:, :t], k[:, :t], v[:, :t], g[:, :t], chunk=4)
    o_step, s2 = gla_decode_step(q[:, t], k[:, t], v[:, t], g[:, t], s)
    full, s_full = gla_chunked(q, k, v, g, chunk=4)
    assert np.abs(np.asarray(o_step) - np.asarray(full[:, t])).max() < 1e-4
    assert np.abs(np.asarray(s2) - np.asarray(s_full)).max() < 1e-4


# ----------------------------------------------------------------------
# MoE specifics
# ----------------------------------------------------------------------
def test_moe_aux_loss_and_capacity():
    cfg = REDUCED["qwen2-moe-a2.7b"]
    from repro.models.moe import moe_apply, moe_params

    p = moe_params(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    out, aux = moe_apply(x, p, cfg)
    assert out.shape == x.shape
    assert float(aux) > 0.0
    assert np.isfinite(np.asarray(out)).all()


def test_head_padding_is_inert():
    """padded_n_heads > n_heads must not change the function."""
    import dataclasses as dc

    base = REDUCED["starcoder2-7b"]
    cfg_nopad = dc.replace(base, n_heads=6, n_kv_heads=2, tp_degree=1)
    cfg_pad = dc.replace(base, n_heads=6, n_kv_heads=2, tp_degree=4)
    assert cfg_pad.padded_n_heads == 8
    p_nopad = init_params(cfg_nopad, KEY)
    p_pad = init_params(cfg_pad, KEY)

    # copy the true-head weights into the padded model
    def graft(small, big, dh):
        big = dict(big)
        return big

    batch = random_batch(cfg_pad, 2, 12, KEY)
    l1 = build_loss_fn(cfg_pad, remat=False, attn_block=8)(p_pad, batch)
    assert np.isfinite(float(l1))
    # inertness: zeroing padded wo rows is done at init; verify
    dh = cfg_pad.resolved_head_dim
    wo = p_pad["layers"]["attn"]["wo"]
    assert np.abs(np.asarray(wo[:, cfg_pad.n_heads * dh :, :])).max() == 0.0
