"""Paper §4–§5: equivalent lengths, the PM schedule, baselines, aggregation.

Property tests check the exact invariants the paper proves:
  * Definition 1 algebra (series additivity, parallel p-norm, associativity)
  * Theorem 6: makespan == equivalent length / p^α; schedule validity per §4
  * Lemma 4: constant ratios; siblings complete simultaneously
  * optimality: PM beats arbitrary constant-share schedules
  * §7 aggregation: no sub-unit shares, work conserved
"""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; skip if absent
from hypothesis import given, strategies as st

from repro.core import (
    Profile,
    TaskTree,
    aggregate,
    divisible_makespan,
    equivalent_length,
    from_pm,
    min_task_share,
    parallel,
    pm_makespan_constant_p,
    pm_schedule,
    proportional_makespan,
    proportional_schedule,
    random_assembly_tree,
    series,
    simulate_constant_shares,
    strategies_comparison,
    task,
    tree_equivalent_lengths,
    tree_pm_windows,
)


# ----------------------------------------------------------------------
# strategies
# ----------------------------------------------------------------------
alphas = st.floats(min_value=0.55, max_value=0.98)


@st.composite
def small_trees(draw, max_n=40):
    n = draw(st.integers(min_value=1, max_value=max_n))
    seed = draw(st.integers(min_value=0, max_value=2**31))
    rng = np.random.default_rng(seed)
    parent = np.full(n, -1, dtype=np.int64)
    for i in range(1, n):
        parent[i] = int(rng.integers(0, i))
    lengths = rng.uniform(0.1, 10.0, size=n)
    return TaskTree(parent=parent, lengths=lengths)


# ----------------------------------------------------------------------
# Definition 1 algebra
# ----------------------------------------------------------------------
@given(alphas, st.floats(0.1, 50), st.floats(0.1, 50))
def test_parallel_composition_formula(alpha, l1, l2):
    g = parallel(task(l1), task(l2))
    expect = (l1 ** (1 / alpha) + l2 ** (1 / alpha)) ** alpha
    assert equivalent_length(g, alpha) == pytest.approx(expect, rel=1e-12)


@given(alphas, st.floats(0.1, 50), st.floats(0.1, 50))
def test_series_additivity(alpha, l1, l2):
    g = series(task(l1), task(l2))
    assert equivalent_length(g, alpha) == pytest.approx(l1 + l2, rel=1e-12)


@given(alphas, st.floats(0.1, 20), st.floats(0.1, 20), st.floats(0.1, 20))
def test_parallel_associative(alpha, a, b, c):
    g1 = parallel(task(a), parallel(task(b), task(c)))
    g2 = parallel(parallel(task(a), task(b)), task(c))
    g3 = parallel(task(a), task(b), task(c))
    e1 = equivalent_length(g1, alpha)
    assert e1 == pytest.approx(equivalent_length(g2, alpha), rel=1e-12)
    assert e1 == pytest.approx(equivalent_length(g3, alpha), rel=1e-12)


@given(alphas, st.floats(0.1, 20), st.floats(0.1, 20))
def test_parallel_bounds(alpha, a, b):
    """max(a,b) ≤ 𝓛(a‖b) ≤ a+b — tree parallelism helps, never hurts."""
    e = equivalent_length(parallel(task(a), task(b)), alpha)
    assert max(a, b) - 1e-12 <= e <= a + b + 1e-12


# ----------------------------------------------------------------------
# Theorem 6 / Lemma 4
# ----------------------------------------------------------------------
@given(small_trees(), alphas, st.floats(2.0, 100.0))
def test_pm_schedule_valid_and_optimal_makespan(tree, alpha, p):
    prof = Profile.constant(p)
    sched = from_pm(tree, alpha, prof)
    sched.validate(tree, prof)
    eq = tree_equivalent_lengths(tree, alpha)
    assert sched.makespan() == pytest.approx(eq[tree.root] / p**alpha, rel=1e-9)


@given(small_trees(max_n=20), alphas)
def test_siblings_finish_simultaneously(tree, alpha):
    w_start, w_end, ratio = tree_pm_windows(tree, alpha)
    ch = tree.children_lists()
    for i in range(tree.n):
        kids = ch[i]
        if len(kids) >= 2:
            ends = [w_end[c] for c in kids]
            assert max(ends) - min(ends) < 1e-9 * max(1.0, max(ends))


@given(small_trees(max_n=25), alphas, st.integers(0, 2**31))
def test_pm_beats_random_constant_share_schedules(tree, alpha, seed):
    rng = np.random.default_rng(seed)
    p = 16.0
    eq = tree_equivalent_lengths(tree, alpha)
    m_pm = eq[tree.root] / p**alpha
    # random speedup-unaware allocation: shares proportional to random weights
    w = rng.uniform(0.1, 1.0, size=tree.n)
    from repro.core.baselines import subtree_weights

    sub = subtree_weights(tree) * w
    ch = tree.children_lists()
    share = np.zeros(tree.n)
    share[tree.root] = p
    for i in tree.topo_order()[::-1]:
        kids = ch[i]
        if kids:
            denom = sum(sub[c] for c in kids)
            for c in kids:
                share[c] = share[i] * sub[c] / denom
    sched = simulate_constant_shares(tree, share, Profile.constant(p), alpha)
    sched.validate(tree, Profile.constant(p))
    assert sched.makespan() >= m_pm - 1e-9 * m_pm


def test_pm_under_step_profile_elastic(rng):
    tree = random_assembly_tree(100, rng)
    alpha = 0.9
    prof = Profile.of([(0.5, 40.0), (1.0, 24.0), (np.inf, 40.0)])
    sched = from_pm(tree, alpha, prof)
    sched.validate(tree, prof)
    eq = tree_equivalent_lengths(tree, alpha)
    assert sched.makespan() == pytest.approx(
        prof.time_for_work(eq[tree.root], alpha), rel=1e-9
    )


def test_profile_work_inversion_roundtrip():
    prof = Profile.of([(1.0, 10.0), (2.0, 4.0), (np.inf, 8.0)])
    for alpha in (0.6, 0.85, 1.0):
        for t in (0.1, 0.9, 1.5, 3.5, 10.0):
            w = prof.work_until(t, alpha)
            assert prof.time_for_work(w, alpha) == pytest.approx(t, rel=1e-9)


# ----------------------------------------------------------------------
# §7 baselines + aggregation
# ----------------------------------------------------------------------
@given(small_trees(max_n=30), alphas)
def test_strategy_ordering(tree, alpha):
    p = 40.0
    m_pm, m_prop, m_div = strategies_comparison(tree, alpha, p)
    assert m_pm <= m_prop + 1e-9 * m_prop
    # DIVISIBLE is only dominated when there is real tree parallelism;
    # PM never loses to it:
    assert m_pm <= m_div + 1e-9 * m_div


def test_proportional_simulation_matches_recursion(rng):
    tree = random_assembly_tree(120, rng)
    alpha = 0.8
    m = proportional_makespan(tree, alpha, 40.0)
    sched = proportional_schedule(tree, alpha, 40.0)
    assert sched.makespan() == pytest.approx(m, rel=1e-6)


def test_divisible_is_total_work(rng):
    tree = random_assembly_tree(50, rng)
    assert divisible_makespan(tree, 0.9, Profile.constant(10.0)) == pytest.approx(
        tree.lengths.sum() / 10.0**0.9
    )


@given(small_trees(max_n=30), alphas)
def test_aggregation_invariants(tree, alpha):
    p = 40.0
    sp = tree.to_sp()
    ag = aggregate(sp, alpha, p)
    assert min_task_share(ag, alpha, p) >= 1.0 - 1e-9
    assert ag.total_length() == pytest.approx(sp.total_length(), rel=1e-9)
    # aggregation can only lengthen the optimal fluid makespan
    assert (
        pm_makespan_constant_p(ag, alpha, p)
        >= pm_makespan_constant_p(sp, alpha, p) - 1e-9
    )


def test_pm_schedule_sp_graph_ratios():
    """Flow conservation: a series node's children inherit its ratio; a
    parallel composition splits it by 𝓛^{1/α} (Lemma 4)."""
    alpha = 0.8
    g = series(parallel(task(3.0, label=0), task(5.0, label=1)), task(2.0, label=2))
    sched = pm_schedule(g, alpha)
    ratios = {iv.label: iv.ratio for iv in sched.intervals}
    assert ratios[2] == pytest.approx(1.0)  # the series tail gets everything
    l3, l5 = 3 ** (1 / alpha), 5 ** (1 / alpha)
    assert ratios[0] == pytest.approx(l3 / (l3 + l5), rel=1e-9)
    assert ratios[1] == pytest.approx(l5 / (l3 + l5), rel=1e-9)
    # both branches span the same work window and end together
    ivs = {iv.label: iv for iv in sched.intervals}
    assert ivs[0].w_end == pytest.approx(ivs[1].w_end, rel=1e-12)
