"""The workload frontend: op-DAG IR, tree-ification, calibrated costs,
the model-zoo builders, the facade entry point, and the mixed-platform
two-node FPTAS.  Every config in the zoo must compile into a §4-valid
malleable task tree and flow through plan/simulate/serve unchanged."""
import json
import subprocess
import sys

import numpy as np
import pytest

from repro.api import MixedCluster, Problem, Schedule, Session, SharedMemory
from repro.configs import ARCHS, SOLVER
from repro.core.hetero import (
    NodeSpec,
    hetero_fptas,
    mixed_hetero_fptas,
    mixed_lower_bound,
    mixed_partition_makespan,
)
from repro.workloads import (
    CALIBRATIONS,
    Op,
    OpGraph,
    Workload,
    analyze,
    calibration_for,
    moe_dispatch,
    default_workload,
    pipeline,
    serving_pod,
    task_lengths,
    treeify,
)

ALPHA = 0.9


# ----------------------------------------------------------------------
# IR + tree-ification
# ----------------------------------------------------------------------
def test_opgraph_validates_deps_cycles_and_duplicates():
    with pytest.raises(ValueError, match="unknown op"):
        OpGraph([Op("a", deps=("ghost",))])
    with pytest.raises(ValueError, match="duplicate"):
        OpGraph([Op("a"), Op("a")])
    with pytest.raises(ValueError, match="cycle"):
        OpGraph([Op("a", deps=("b",)), Op("b", deps=("a",))])
    with pytest.raises(ValueError, match="non-negative"):
        Op("a", flops=-1.0)


def test_series_contraction_fuses_chains_and_conserves_work():
    g = OpGraph([
        Op("a", flops=1.0, out_bytes=10.0),
        Op("b", flops=2.0, deps=("a",), out_bytes=20.0),
        Op("c", flops=4.0, deps=("b",), out_bytes=40.0),
    ])
    tf = treeify(g)
    # a pure chain contracts to one task carrying the summed work
    assert tf.n_tasks == 1
    assert tf.flops[0] == pytest.approx(7.0)
    assert sorted(tf.op_map[0]) == ["a", "b", "c"]
    assert tf.relaxed_edges == []
    # ...whose handoff is the *sink* op's activation, not the chain's sum
    assert tf.out_bytes[0] == pytest.approx(40.0)


def test_group_tags_block_cross_stage_fusion():
    g = OpGraph([
        Op("a", flops=1.0, group="s0"),
        Op("b", flops=2.0, deps=("a",), group="s0"),
        Op("c", flops=4.0, deps=("b",), group="s1"),
    ])
    tf = treeify(g)
    assert tf.n_tasks == 2  # s0 chain fuses, the stage boundary holds
    assert sorted(map(sorted, tf.op_map)) == [["a", "b"], ["c"]]
    # in-tree: s0 feeds s1
    [s0] = [i for i, ops in enumerate(tf.op_map) if "a" in ops]
    [s1] = [i for i, ops in enumerate(tf.op_map) if "c" in ops]
    assert tf.tree.parent[s0] == s1


def test_fanout_relaxes_extra_edges_and_records_them():
    g = OpGraph([
        Op("src", flops=1.0),
        Op("l", flops=2.0, deps=("src",)),
        Op("r", flops=3.0, deps=("src",)),
        Op("join", flops=1.0, deps=("l", "r")),
    ])
    tf = treeify(g)
    assert tf.n_tasks == 4
    assert len(tf.relaxed_edges) == 1
    assert tf.relaxed_edges[0][0] == "src"  # the dropped producer edge
    # work is conserved exactly across the rewrite
    assert tf.flops.sum() == pytest.approx(g.total_flops())


def test_multiple_sinks_join_under_zero_cost_virtual_root():
    g = OpGraph([Op("a", flops=1.0), Op("b", flops=2.0)])
    tf = treeify(g)
    assert tf.n_tasks == 3
    root = int(np.flatnonzero(tf.tree.parent == -1)[0])
    assert tf.op_map[root] == []  # virtual
    assert tf.flops[root] == 0.0
    assert tf.flops.sum() == pytest.approx(3.0)


def test_meta_block_is_json_serializable_provenance():
    tf = treeify(OpGraph([Op("a", flops=1.0), Op("b", flops=2.0, deps=("a",))]))
    meta = json.loads(json.dumps(tf.meta()))
    assert meta["n_ops"] == 2
    assert sorted(sum(meta["op_map"].values(), [])) == ["a", "b"]


# ----------------------------------------------------------------------
# Cost model
# ----------------------------------------------------------------------
def test_task_lengths_follow_the_roofline():
    # two independent ops (→ virtual root): one compute-bound, one
    # bandwidth-bound; each task's length is its binding resource's time
    tf = treeify(OpGraph([
        Op("compute", flops=1e12, bytes=1.0),
        Op("memory", flops=1.0, bytes=1e12),
    ]))
    cal = CALIBRATIONS["tpu"]
    lengths = task_lengths(tf, cal)
    assert lengths.shape == (tf.n_tasks,)
    assert lengths[0] == pytest.approx(1e12 / cal.flop_rate)
    assert lengths[1] == pytest.approx(1e12 / cal.mem_bw)
    assert lengths[2] == 0.0  # the virtual root costs nothing


def test_calibration_for_duck_types_on_platform_name():
    assert calibration_for(SharedMemory(8)).name == "cpu"
    mixed = MixedCluster([SharedMemory(4), 2])
    assert calibration_for(mixed).name in CALIBRATIONS


# ----------------------------------------------------------------------
# Zoo builders: every config compiles to a §4-valid schedule
# ----------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(ARCHS))
def test_every_zoo_config_plans_valid_under_pm_and_online(name):
    wl = default_workload(ARCHS[name])
    assert isinstance(wl, Workload)
    prob = wl.problem(SharedMemory(16))
    assert prob.n >= 2
    assert np.all(np.asarray(prob.tree.lengths) >= 0)
    assert prob.meta and prob.meta["workload"]["kind"] == wl.kind

    sess = Session(SharedMemory(16)).load(prob)
    sched = sess.plan(policy="pm").schedule
    sched.validate(prob)
    # op-provenance rides the Problem into the Schedule meta
    assert sched.meta["workload"]["n_ops"] == wl.graph.n_ops

    rep = sess.simulate(policy="pm")
    assert rep.makespan == pytest.approx(sched.makespan, rel=1e-9)

    # JSON v2 round-trip keeps the provenance block intact
    back = Schedule.from_json(sched.to_json())
    assert back.meta["workload"]["op_map"] == sched.meta["workload"]["op_map"]
    back.validate(prob)


def test_moe_dispatch_star_shape_and_skew():
    cfg = ARCHS["qwen2-moe-a2.7b"]
    wl = moe_dispatch(cfg, skew=1.0)
    assert wl.kind == "moe"
    # star: every expert's parent is the router/backbone root
    tf = wl.treeified
    root = int(np.flatnonzero(tf.tree.parent == -1)[0])
    children = np.flatnonzero(tf.tree.parent == root)
    assert len(children) == cfg.moe.n_experts
    # Zipf skew orders the expert loads
    loads = tf.flops[children]
    assert loads.max() > loads.min()


def test_pipeline_contracts_to_stage_chain():
    wl = pipeline(ARCHS["qwen3-4b"], stages=4)
    assert wl.kind == "pipeline"
    n = wl.treeified.n_tasks
    assert n <= 4 + 2  # stages (+ embed/head fused at the ends)
    # a chain has exactly one leaf
    parents = wl.treeified.tree.parent
    assert sum(1 for t in range(n) if t not in set(parents.tolist())) == 1


def test_serving_pod_namespaces_and_joins_models():
    pod = serving_pod(["qwen3-4b", "rwkv6-1.6b"])
    assert pod.kind == "pod"
    names = [op.name for op in pod.graph.ops]
    assert all(n.startswith(("m0.", "m1.")) for n in names)
    prob = pod.problem(SharedMemory(16))
    root = int(np.flatnonzero(np.asarray(prob.tree.parent) == -1)[0])
    assert prob.tree.lengths[root] == 0.0  # virtual join


def test_analyze_dispatches_models_pods_and_sparse():
    p = SharedMemory(16)
    assert analyze("qwen3-4b", p).meta["workload"]["kind"] == "pipeline"
    assert analyze(["qwen3-4b", "rwkv6-1.6b"], p).meta["workload"]["kind"] == "pod"
    sp = analyze("sparse", p)
    assert sp.meta["workload"]["kind"] == "sparse"
    assert sp.n > 100  # the SOLVER grid's multifrontal tree
    assert analyze(SOLVER.name, p).n == sp.n
    with pytest.raises((KeyError, ValueError)):
        analyze("no-such-model", p)


# ----------------------------------------------------------------------
# Facade: Session.analyze_workload end-to-end
# ----------------------------------------------------------------------
@pytest.mark.parametrize(
    "name,shape",
    [
        ("qwen2-moe-a2.7b", "decode_32k"),
        ("granite-moe-3b-a800m", "decode_32k"),
        ("qwen3-4b", "prefill_32k"),
        ("qwen2.5-3b", "train_4k"),
        ("rwkv6-1.6b", "decode_32k"),
        ("starcoder2-7b", "prefill_32k"),
    ],
)
def test_analyze_workload_plans_and_simulates(name, shape):
    sess = Session(SharedMemory(32)).analyze_workload(name, shape=shape)
    sched = sess.plan(policy="pm").schedule
    sched.validate(sess.problem)
    rep = sess.simulate(policy="pm")
    assert rep.makespan > 0
    assert sched.meta["workload"]["model"] == name


def test_analyze_workload_memory_footprints_enforced():
    sess = Session(SharedMemory(32)).analyze_workload(
        "qwen3-4b", shape="prefill_32k"
    )
    assert sess.problem.memory_footprints() is not None
    sched = sess.plan(policy="pm").schedule
    assert sched.peak_memory() > 0


def test_analyze_workload_serves_in_process():
    reqs = [("qwen3-4b", 0), ("rwkv6-1.6b", 1), ("qwen3-4b", 0)]
    sess = Session(SharedMemory(32))
    stream = [
        (analyze(n, SharedMemory(32)), 0.0, t) for n, t in reqs
    ]
    rep = sess.serve(
        stream, admission="fair", max_concurrent=2,
        qos_weights={0: 4.0, 1: 1.0},
    )
    online = rep.detail
    assert len(online.futures) == 3
    assert all(f.state == "done" for f in online.futures.values())
    assert rep.metrics["mean_latency"] > 0


def test_hlo_estimator_rescales_analytic_lengths():
    wl = pipeline(ARCHS["qwen3-4b"])
    a = wl.problem(SharedMemory(8), estimator="analytic")
    h = wl.problem(SharedMemory(8), estimator="hlo")
    ra = np.asarray(a.tree.lengths)
    rh = np.asarray(h.tree.lengths)
    mask = ra > 0
    scale = rh[mask] / ra[mask]
    # one global XLA-vs-analytic flop scale, applied uniformly
    assert scale.std() / scale.mean() < 1e-6
    assert 0.1 < scale.mean() < 10.0


# ----------------------------------------------------------------------
# Mixed-platform two-node FPTAS (§6.2 generalized)
# ----------------------------------------------------------------------
def test_mixed_fptas_matches_homogeneous_algorithm_12(rng):
    works = rng.uniform(0.5, 5.0, 24)
    node_p = NodeSpec(6.0, ALPHA)
    node_q = NodeSpec(3.0, ALPHA)
    res = mixed_hetero_fptas(works, node_p, node_q, lam=1.05)
    legacy = hetero_fptas(works, 6.0, 3.0, ALPHA, lam=1.05)
    # same α, unit speeds: the mixed result can only match or beat the
    # legacy bound since it scores every candidate exactly
    assert res.makespan <= legacy.makespan * 1.05 + 1e-12
    assert res.makespan >= res.lower_bound - 1e-9
    # the partition is a partition
    assert sorted(res.on_p + res.on_q) == list(range(24))
    assert res.makespan == pytest.approx(
        mixed_partition_makespan(works, res.on_p, node_p, node_q)
    )


def test_mixed_fptas_prefers_fast_node_for_everything_small(rng):
    works = rng.uniform(0.5, 1.0, 8)
    slow = NodeSpec(4.0, 0.85, speed=1.0)
    fast = NodeSpec(4.0, 0.95, speed=100.0)
    res = mixed_hetero_fptas(works, slow, fast, lam=1.05)
    assert len(res.on_q) >= len(res.on_p)  # bulk lands on the fast node
    assert res.makespan >= mixed_lower_bound(works, slow, fast) - 1e-9


def test_mixed_cluster_policy_end_to_end(rng):
    works = rng.uniform(0.5, 3.0, 16)
    platform = MixedCluster(
        [SharedMemory(40), 8], alphas=(0.85, 0.95), speeds=(1.0, 4.0)
    )
    prob = Problem.from_lengths(works, 0.9)
    sched = Session(platform).load(prob).plan(policy="hetero-mixed").schedule
    assert sched.makespan >= sched.fluid_makespan - 1e-9
    placed = {lbl for lbl, _ in sched.meta["placement"]}
    assert len(placed) == 16
    assert set(n for _, n in sched.meta["placement"]) <= {0, 1}


def test_mixed_cluster_validates_construction():
    with pytest.raises(ValueError):
        MixedCluster([4, 4], alphas=(0.9, 1.5))  # α out of (0, 1]
    with pytest.raises(ValueError):
        MixedCluster([4, 4], speeds=(1.0, -2.0))
    # the policy needs exactly two nodes to run Algorithm 12 on
    one = MixedCluster([SharedMemory(4)])
    with pytest.raises(ValueError):
        Session(one).load(Problem.from_lengths([1.0, 2.0], 0.9)).plan(
            policy="hetero-mixed"
        )


# ----------------------------------------------------------------------
# Laziness: the facade must not drag the zoo into light-weight sessions
# ----------------------------------------------------------------------
def test_plain_session_never_imports_the_model_zoo():
    code = (
        "import sys\n"
        "from repro import Session, SharedMemory\n"
        "from repro.sparse import grid_laplacian_2d, nested_dissection_2d\n"
        "from repro.api import Problem\n"
        "a = grid_laplacian_2d(9)\n"
        "prob = Problem.from_matrix(a, 0.9, ordering=nested_dissection_2d(9))\n"
        "s = Session(SharedMemory(8)).load(prob).plan('pm')\n"
        "s.simulate()\n"
        "heavy = [m for m in sys.modules if m.startswith(\n"
        "    ('repro.workloads', 'repro.models', 'repro.configs'))]\n"
        "assert not heavy, heavy\n"
        "print('lazy-ok')\n"
    )
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=120,
    )
    assert out.returncode == 0, out.stderr
    assert "lazy-ok" in out.stdout
