"""The assigned-architecture configs must match the assignment sheet
exactly (guards against dimension drift)."""
import pytest

from repro.configs import ARCHS, SOLVER

# (layers, d_model, heads, kv, d_ff, vocab, family)
ASSIGNMENT = {
    "qwen3-4b": (36, 2560, 32, 8, 9728, 151_936, "dense"),
    "starcoder2-7b": (32, 4608, 36, 4, 18_432, 49_152, "dense"),
    "qwen2.5-3b": (36, 2048, 16, 2, 11_008, 151_936, "dense"),
    "qwen2.5-32b": (64, 5120, 40, 8, 27_648, 152_064, "dense"),
    "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151_936, "moe"),
    "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49_155, "moe"),
    "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65_536, "ssm"),
    "pixtral-12b": (40, 5120, 32, 8, 14_336, 131_072, "vlm"),
    "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256_206, "audio"),
    "zamba2-2.7b": (54, 2560, 32, 32, 10_240, 32_000, "hybrid"),
}


@pytest.mark.parametrize("name", sorted(ASSIGNMENT))
def test_config_matches_assignment(name):
    cfg = ARCHS[name]
    l, d, h, kv, ff, v, fam = ASSIGNMENT[name]
    assert cfg.n_layers == l
    assert cfg.d_model == d
    assert cfg.n_heads == h
    assert cfg.n_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v
    assert cfg.family == fam


def test_moe_details():
    q = ARCHS["qwen2-moe-a2.7b"].moe
    assert (q.n_experts, q.top_k, q.n_shared) == (60, 4, 4)
    g = ARCHS["granite-moe-3b-a800m"].moe
    assert (g.n_experts, g.top_k) == (40, 8)


def test_ssm_details():
    assert ARCHS["rwkv6-1.6b"].ssm.kind == "rwkv6"
    z = ARCHS["zamba2-2.7b"]
    assert z.ssm.kind == "mamba2" and z.ssm.d_state == 64
    assert z.hybrid_attn_every == 6


def test_encdec_and_frontends():
    s = ARCHS["seamless-m4t-large-v2"]
    assert s.encdec and s.n_encoder_layers == 24 and s.frontend == "frames"
    assert ARCHS["pixtral-12b"].frontend == "patch"


def test_solver_config():
    assert SOLVER.name == "multifrontal-cholesky"
    assert 0 < SOLVER.alpha <= 1.0
