"""Pallas frontal-factorization kernels vs the pure-jnp oracle.

Sweeps shapes and dtypes in interpret mode (CPU container; on TPU the same
calls lower to Mosaic).  Covers both execution paths: the VMEM-resident
whole-front kernel and the panel+SYRK large-front pipeline.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.ops as ops
from repro.kernels.frontal_cholesky import TILE, panel_factor, syrk_downdate
from repro.kernels.ref import panel_factor_ref, partial_cholesky_ref, syrk_update_ref


def _spd(m, rng, dtype=np.float32):
    b = rng.normal(size=(m, m)).astype(np.float64)
    a = b @ b.T + m * np.eye(m)
    return a.astype(dtype)


@pytest.mark.parametrize(
    "m,nb",
    [(16, 8), (32, 32), (100, 60), (128, 128), (192, 64), (256, 128),
     (300, 140), (384, 256)],
)
def test_partial_cholesky_matches_ref_f32(m, nb, rng):
    f = jnp.asarray(_spd(m, rng))
    pan, sch = ops.partial_cholesky(f, nb)
    pr, sr = partial_cholesky_ref(f, nb)
    scale = max(1.0, float(jnp.abs(pr).max()))
    assert np.abs(np.asarray(pan) - np.asarray(pr)).max() / scale < 5e-5
    if sch.size:
        s2 = max(1.0, float(jnp.abs(sr).max()))
        assert np.abs(np.asarray(sch) - np.asarray(sr)).max() / s2 < 5e-5


def test_partial_cholesky_f64(rng):
    jax.config.update("jax_enable_x64", True)
    try:
        f = jnp.asarray(_spd(96, rng, np.float64))
        pan, sch = ops.partial_cholesky(f, 48)
        pr, sr = partial_cholesky_ref(f, 48)
        assert np.abs(np.asarray(pan) - np.asarray(pr)).max() < 1e-11
        assert np.abs(np.asarray(sch) - np.asarray(sr)).max() < 1e-11
    finally:
        jax.config.update("jax_enable_x64", False)


def test_large_front_panel_path(rng, monkeypatch):
    monkeypatch.setattr(ops, "VMEM_FRONT_MAX", 256)
    monkeypatch.setattr(ops, "OUTER_PANEL", 256)
    f = jnp.asarray(_spd(520, rng))
    pan, sch = ops.partial_cholesky(f, 384)
    pr, sr = partial_cholesky_ref(f, 384)
    scale = max(1.0, float(jnp.abs(pr).max()))
    assert np.abs(np.asarray(pan) - np.asarray(pr)).max() / scale < 1e-4
    s2 = max(1.0, float(jnp.abs(np.asarray(sr)).max()))
    assert np.abs(np.asarray(sch) - np.asarray(sr)).max() / s2 < 1e-4


def test_panel_factor_kernel(rng):
    mp, nb = 256, TILE
    slab = np.zeros((mp, nb), np.float32)
    a = _spd(mp, rng)
    slab[:, :] = a[:, :nb]
    out = panel_factor(jnp.asarray(slab), interpret=True)
    ref = panel_factor_ref(jnp.asarray(slab))
    tri = np.tril(np.ones((nb, nb), bool))
    got, want = np.asarray(out), np.asarray(ref)
    scale = max(1.0, np.abs(want).max())
    assert np.abs(np.where(tri, got[:nb], 0) - np.where(tri, want[:nb], 0)).max() / scale < 5e-5
    assert np.abs(got[nb:] - want[nb:]).max() / scale < 5e-5


@pytest.mark.parametrize("m,k,tile", [(256, 128, 128), (512, 256, 256)])
def test_syrk_downdate_kernel(m, k, tile, rng):
    c = rng.normal(size=(m, m)).astype(np.float32)
    a = rng.normal(size=(m, k)).astype(np.float32)
    out = syrk_downdate(jnp.asarray(c), jnp.asarray(a), tile=tile, interpret=True)
    ref = syrk_update_ref(jnp.asarray(c), jnp.asarray(a))
    assert np.abs(np.asarray(out) - np.asarray(ref)).max() < 1e-2  # |C|~k


def test_multifrontal_with_pallas_kernel(rng):
    from repro.kernels.ops import factor_fn
    from repro.sparse import (
        analyze,
        factorize,
        grid_laplacian_2d,
        nested_dissection_2d,
        permute_symmetric,
    )

    a = grid_laplacian_2d(13, 13)
    ap = permute_symmetric(a, nested_dissection_2d(13, 13))
    symb = analyze(ap, relax=2)
    fact = factorize(ap, symb, factor_fn=factor_fn())
    l = fact.to_dense_l()
    assert np.abs(l @ l.T - ap.toarray()).max() < 5e-4


def test_padding_pivots_are_inert(rng):
    """nb not a multiple of 128: padded pivots must not change results."""
    f = jnp.asarray(_spd(160, rng))
    pan, sch = ops.partial_cholesky(f, 37)
    pr, sr = partial_cholesky_ref(f, 37)
    scale = max(1.0, float(jnp.abs(pr).max()))
    assert np.abs(np.asarray(pan) - np.asarray(pr)).max() / scale < 5e-5
    assert np.abs(np.asarray(sch) - np.asarray(sr)).max() / max(
        1.0, float(jnp.abs(sr).max())
    ) < 5e-5


# ----------------------------------------------------------------------
# flash attention kernel (§Perf fix for the dense-train cells)
# ----------------------------------------------------------------------
@pytest.mark.parametrize(
    "b,t,h,dh,bq,bkv,causal",
    [(1, 64, 2, 16, 16, 16, True), (2, 128, 3, 32, 32, 64, True),
     (1, 64, 2, 16, 32, 16, False), (1, 96, 1, 8, 32, 32, True)],
)
def test_flash_attention_matches_naive(b, t, h, dh, bq, bkv, causal):
    from repro.kernels.flash_attention import flash_attention

    key = jax.random.PRNGKey(b * 7 + t)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, t, h, dh))
    k = jax.random.normal(ks[1], (b, t, h, dh))
    v = jax.random.normal(ks[2], (b, t, h, dh))
    o = flash_attention(q, k, v, causal=causal, block_q=bq, block_kv=bkv,
                        interpret=True)
    scale = dh**-0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q * scale, k)
    if causal:
        mask = jnp.tril(jnp.ones((t, t), bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(logits, -1), v)
    assert np.abs(np.asarray(o) - np.asarray(ref)).max() < 2e-5
