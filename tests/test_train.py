"""Training substrate: optimizer, grad accumulation, data, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import ARCHS
from repro.data import DataConfig, SyntheticTokens, with_extras
from repro.models import init_params, random_batch
from repro.train import (
    OptConfig,
    adamw_update,
    build_train_step,
    init_opt_state,
    lr_at,
)

KEY = jax.random.PRNGKey(0)


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_opt_state(params)
    cfg = OptConfig(lr=0.3, weight_decay=0.0, warmup_steps=0, total_steps=200)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 1e-2


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros(3)}
    state = init_opt_state(params)
    cfg = OptConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0, warmup_steps=0)
    g = {"w": jnp.array([1e6, 0.0, 0.0])}
    _, _, stats = adamw_update(params, g, state, cfg)
    assert float(stats["grad_norm"]) == pytest.approx(1e6)


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(lr_at(jnp.asarray(0), cfg)) < 0.2
    assert float(lr_at(jnp.asarray(9), cfg)) == pytest.approx(1.0, abs=0.01)
    assert float(lr_at(jnp.asarray(99), cfg)) == pytest.approx(0.1, abs=0.02)


def test_train_step_reduces_loss():
    cfg = ARCHS["qwen2.5-3b"].reduced()
    params = init_params(cfg, KEY)
    opt = init_opt_state(params)
    step = build_train_step(cfg, OptConfig(lr=5e-3, warmup_steps=0), remat=True,
                            attn_block=8)
    batch = random_batch(cfg, 4, 16, KEY)  # overfit one batch
    losses = []
    for _ in range(8):
        params, opt, stats = step(params, opt, batch)
        losses.append(float(stats["loss"]))
    assert losses[-1] < losses[0]


def test_microbatching_matches_full_batch_grads():
    cfg = ARCHS["qwen3-4b"].reduced()
    params = init_params(cfg, KEY)
    batch = random_batch(cfg, 4, 16, KEY)
    from repro.models import build_loss_fn

    loss_fn = build_loss_fn(cfg, remat=False, attn_block=8)
    g_full = jax.grad(loss_fn)(params, batch)
    # mean of per-microbatch grads (equal sizes) == full-batch grad since the
    # loss is a token mean over equal-token microbatches
    micro = jax.tree.map(lambda a: a.reshape((2, 2) + a.shape[1:]), batch)
    g_acc = jax.tree.map(jnp.zeros_like, g_full)
    for i in range(2):
        mb = jax.tree.map(lambda a: a[i], micro)
        g = jax.grad(loss_fn)(params, mb)
        g_acc = jax.tree.map(lambda a, b: a + b / 2, g_acc, g)
    flat1 = jnp.concatenate([x.ravel() for x in jax.tree.leaves(g_full)])
    flat2 = jnp.concatenate([x.ravel() for x in jax.tree.leaves(g_acc)])
    assert float(jnp.abs(flat1 - flat2).max()) < 2e-5


def test_data_pipeline_determinism_and_packing():
    dc = DataConfig(vocab_size=1000, seq_len=64, global_batch=4, seed=7)
    ds = SyntheticTokens(dc)
    b1 = ds.batch_at(3)
    b2 = ds.batch_at(3)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 64)
    assert b1["tokens"].max() < 1000
    # different steps differ
    assert not np.array_equal(ds.batch_at(4)["tokens"], b1["tokens"])
    # extras for modality archs
    b3 = with_extras(b1, ARCHS["pixtral-12b"].reduced())
    assert "patches" in b3


def test_checkpoint_roundtrip(tmp_path):
    cfg = ARCHS["rwkv6-1.6b"].reduced()
    params = init_params(cfg, KEY)
    opt = init_opt_state(params)
    ck = Checkpointer(str(tmp_path), keep=2)
    state = {"params": params, "opt": opt}
    ck.save(10, state)
    ck.save(20, state, async_save=True)
    ck.wait()
    assert ck.all_steps() == [10, 20]
    step, restored = ck.restore(jax.eval_shape(lambda: state))
    assert step == 20
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert np.allclose(np.asarray(a), np.asarray(b))
    # GC keeps only `keep`
    ck.save(30, state)
    assert ck.all_steps() == [20, 30]


def test_checkpoint_atomicity(tmp_path):
    """A stray .tmp dir (simulated crash) must not be visible as a step."""
    ck = Checkpointer(str(tmp_path))
    os.makedirs(tmp_path / "step_00000099.tmp")
    assert ck.all_steps() == []
    ck.save(5, {"x": jnp.ones(3)})
    assert ck.latest_step() == 5
