"""Multifrontal substrate: symbolic + numeric factorization, PM planning."""
import jax
import numpy as np
import pytest
import scipy.sparse as sp

from repro.core.pm import tree_equivalent_lengths
from repro.sparse import (
    analyze,
    etree,
    factorize,
    grid_laplacian_2d,
    grid_laplacian_3d,
    make_plan,
    min_degree,
    nested_dissection_2d,
    partial_factor_flops,
    permute_symmetric,
    random_spd,
    replan_elastic,
    solve,
)


@pytest.fixture(autouse=True)
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


def test_etree_known_example():
    """Arrow matrix: every column hangs off the last one."""
    n = 5
    a = sp.lil_matrix((n, n))
    a.setdiag(10.0)
    a[n - 1, :] = 1.0
    a[:, n - 1] = 1.0
    par = etree(a.tocsr())
    assert all(par[i] == n - 1 for i in range(n - 1))
    assert par[n - 1] == -1


@pytest.mark.parametrize("relax", [0, 2])
def test_grid_2d_factorization(relax):
    a = grid_laplacian_2d(9, 9)
    perm = nested_dissection_2d(9, 9)
    ap = permute_symmetric(a, perm)
    symb = analyze(ap, relax=relax)
    fact = factorize(ap, symb)
    l = fact.to_dense_l()
    assert np.abs(l @ l.T - ap.toarray()).max() < 1e-10
    b = np.arange(symb.n, dtype=float)
    x = solve(fact, b)
    assert np.abs(ap @ x - b).max() < 1e-8


def test_grid_3d_factorization():
    a = grid_laplacian_3d(4)
    symb = analyze(a, relax=1)
    fact = factorize(a, symb)
    l = fact.to_dense_l()
    assert np.abs(l @ l.T - a.toarray()).max() < 1e-10


def test_random_spd_min_degree(rng):
    a = random_spd(50, 4.0, rng)
    p = min_degree(a)
    assert sorted(p) == list(range(50))
    ap = permute_symmetric(a, p)
    symb = analyze(ap, relax=1)
    fact = factorize(ap, symb)
    l = fact.to_dense_l()
    assert np.abs(l @ l.T - ap.toarray()).max() < 1e-8


def test_flops_formula():
    # full Cholesky of dense m×m: ~ m³/3
    m = 64
    f = partial_factor_flops(m, m)
    assert f == pytest.approx(m**3 / 3, rel=0.1)


def test_task_tree_and_plan():
    a = grid_laplacian_2d(15, 15)
    perm = nested_dissection_2d(15, 15)
    ap = permute_symmetric(a, perm)
    symb = analyze(ap, relax=1)
    tree = symb.task_tree()
    assert tree.lengths.sum() > 0
    plan = make_plan(tree, 64, alpha=0.9)
    # precedence: every task starts after its children end
    by_task = {t.task: t for t in plan.tasks}
    for i in range(tree.n):
        p = int(tree.parent[i])
        if p >= 0:
            assert by_task[p].start >= by_task[i].end - 1e-9
    # capacity: at any start event, running device groups fit the mesh
    events = sorted({t.start for t in plan.tasks})
    for ev in events:
        used = sum(
            t.devices for t in plan.tasks if t.start <= ev < t.end
        )
        assert used <= 64
    # plan is never better than the fluid optimum
    assert plan.makespan >= plan.fluid_makespan - 1e-9


def test_wave_order_factorization_matches():
    a = grid_laplacian_2d(11, 11)
    perm = nested_dissection_2d(11, 11)
    ap = permute_symmetric(a, perm)
    symb = analyze(ap)
    tree = symb.task_tree()
    plan = make_plan(tree, 16, alpha=0.85)
    order = [t.label for w in plan.waves() for t in w if t.label >= 0]
    fact = factorize(ap, symb, order=order)
    l = fact.to_dense_l()
    assert np.abs(l @ l.T - ap.toarray()).max() < 1e-10


def test_elastic_replan_work_conservation():
    a = grid_laplacian_2d(13, 13)
    perm = nested_dissection_2d(13, 13)
    symb = analyze(permute_symmetric(a, perm), relax=1)
    tree = symb.task_tree()
    plan = make_plan(tree, 64, alpha=0.9)
    t_evt = plan.makespan * 0.4
    plan2 = replan_elastic(tree, plan, t_evt, 32, 0.9)
    # residual work is at most the original and the new plan is feasible
    assert plan2.makespan > 0
    done_before = sum(
        min(1.0, max(0.0, (t_evt - t.start) / max(t.end - t.start, 1e-12)))
        * tree.lengths[t.task]
        for t in plan.tasks
    )
    assert done_before > 0
    eq_before = tree_equivalent_lengths(tree, 0.9)[tree.root]
    assert plan2.fluid_makespan <= eq_before / 32**0.9 + 1e-9
