"""Malleable-plan executor: CPU interpret-mode end-to-end + unit tests."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.distributed.device_groups import (
    assign_wave_groups,
    groups_footprint,
    pow2_floor,
    scale_group,
)
from repro.runtime.executor import PlanExecutor, execute_plan
from repro.sparse import (
    analyze,
    factorize,
    grid_laplacian_2d,
    make_plan,
    nested_dissection_2d,
    permute_symmetric,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="module")
def problem():
    a = grid_laplacian_2d(9)
    ap = permute_symmetric(a, nested_dissection_2d(9))
    symb = analyze(ap, relax=1)
    plan = make_plan(symb.task_tree(), 8, alpha=0.9)
    return ap, symb, plan


def test_executor_end_to_end(problem):
    ap, symb, plan = problem
    fact, report = execute_plan(ap, symb, plan)
    dense = ap.toarray()
    l = fact.to_dense_l()
    rel = np.abs(l @ l.T - dense).max() / np.abs(dense).max()
    assert rel < 1e-5

    # one trace event per front, all with positive duration bounds
    assert sorted(e.front for e in report.trace) == list(
        range(symb.n_supernodes)
    )
    assert report.measured_makespan > 0
    assert report.n_dispatches <= len(report.trace)
    # trace respects plan precedence: child fronts finish before parents run
    ev = {e.front: e for e in report.trace}
    for s, sn in enumerate(symb.supernodes):
        if sn.parent >= 0:
            assert ev[sn.parent].t_start >= ev[s].t_end - 1e-9
    # report renders and compares measured vs projected
    text = report.summary()
    assert "measured" in text and "projected" in text
    assert report.projected_seconds() > 0
    # single device => no group-size variety => honest n/a, not a number
    assert report.fit_alpha() is None


def test_wave_batching_matches_sequential(problem):
    """Batched padded dispatch must reproduce the sequential driver."""
    ap, symb, plan = problem
    fact_batched, _ = execute_plan(ap, symb, plan)
    fact_seq = factorize(ap, symb)
    for pb, ps in zip(fact_batched.panels, fact_seq.panels):
        np.testing.assert_allclose(pb, ps, rtol=1e-8, atol=1e-8)


def test_executor_proportional_strategy(problem):
    ap, symb, _ = problem
    plan = make_plan(symb.task_tree(), 8, alpha=0.9, strategy="proportional")
    assert plan.strategy == "proportional"
    assert plan.makespan >= plan.fluid_makespan - 1e-9
    fact, _ = execute_plan(ap, symb, plan)
    dense = ap.toarray()
    l = fact.to_dense_l()
    assert np.abs(l @ l.T - dense).max() / np.abs(dense).max() < 1e-5


def test_dispatch_schedule_batches_same_shapes(problem):
    ap, symb, plan = problem
    ex = PlanExecutor(symb, plan)
    ds = ex.dispatches()
    # every front dispatched exactly once
    alls = sorted(s for d in ds for s in d.supernodes)
    assert alls == list(range(symb.n_supernodes))
    # batching actually happens: fewer dispatches than fronts
    assert len(ds) < symb.n_supernodes
    # a dispatch never mixes shape classes or waves
    for d in ds:
        for s in d.supernodes:
            sn = symb.supernodes[s]
            from repro.kernels.ops import padded_shape

            assert padded_shape(sn.m, sn.nb) == d.key


# ----------------------------------------------------------------------
def test_pow2_floor():
    assert [pow2_floor(x) for x in (1, 2, 3, 7, 8, 9)] == [1, 2, 2, 4, 8, 8]


def test_scale_group_downscales_plan():
    # a 64-wide plan group on a 4-device mesh keeps its proportion
    assert scale_group(64, 256, 4) == 1
    assert scale_group(256, 256, 4) == 4
    assert scale_group(8, 8, 8) == 8
    assert scale_group(3, 8, 8) == 2  # pow2 floor when counts match


def test_assign_wave_groups_buddy():
    groups = assign_wave_groups({0: 4, 1: 2, 2: 2}, 8)
    touched, max_load = groups_footprint(groups)
    assert touched == 8 and max_load == 1  # disjoint, fully packed
    assert groups[0].size == 4 and groups[0].offset % 4 == 0
    for g in groups.values():
        assert g.size & (g.size - 1) == 0  # power of two


def test_assign_wave_groups_oversubscribed():
    # more demand than devices: placement degrades to time-sharing, never raises
    groups = assign_wave_groups({i: 2 for i in range(5)}, 4)
    assert len(groups) == 5
    _, max_load = groups_footprint(groups)
    assert max_load >= 2


@pytest.mark.slow
def test_executor_multi_device_forged():
    """Sharded wave dispatch on 4 forged CPU devices (subprocess owns the
    XLA device-forging flag before jax initializes)."""
    code = """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
from repro.sparse import analyze, grid_laplacian_2d, make_plan, \
    nested_dissection_2d, permute_symmetric
from repro.runtime import execute_plan

assert jax.device_count() == 4
a = grid_laplacian_2d(9)
ap = permute_symmetric(a, nested_dissection_2d(9))
symb = analyze(ap, relax=1)
plan = make_plan(symb.task_tree(), 4, alpha=0.9)
fact, rep = execute_plan(ap, symb, plan)
dense = ap.toarray()
l = fact.to_dense_l()
assert np.abs(l @ l.T - dense).max() / np.abs(dense).max() < 1e-5
used = {e.devices_used for e in rep.trace}
assert max(used) > 1, used  # groups actually span devices
print("MULTIDEV_OK", sorted(used), rep.fit_alpha())
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=env,
        timeout=420,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MULTIDEV_OK" in out.stdout
