"""Sharding rules: every sharded dim must divide the production mesh axes.

(The actual 512-device lowering is exercised by the dry-run driver, which
owns the XLA_FLAGS device-forging; these tests validate the *rules* without
touching jax device state.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.models import SHAPES, cell_is_runnable, decode_input_specs, param_specs
from repro.models.model import batch_specs

AXIS_SIZES = {"pod": 2, "data": 16, "model": 16}


def _check_tree(specs, shapes, where):
    leaves_s = jax.tree_util.tree_leaves_with_path(
        specs, is_leaf=lambda x: isinstance(x, P)
    )
    leaves_a = jax.tree_util.tree_leaves(shapes)
    assert len(leaves_s) == len(leaves_a)
    for (path, spec), arr in zip(leaves_s, leaves_a):
        dims = list(spec) + [None] * (arr.ndim - len(spec))
        for i, ax in enumerate(dims):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            total = int(np.prod([AXIS_SIZES[a] for a in axes]))
            assert arr.shape[i] % total == 0, (
                f"{where}: {jax.tree_util.keystr(path)} dim {i} "
                f"({arr.shape[i]}) not divisible by {axes} ({total})"
            )


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_param_specs_divisible(name):
    from repro.distributed.sharding import param_pspecs

    cfg = ARCHS[name]
    shapes = param_specs(cfg, dtype=jnp.bfloat16)
    specs = param_pspecs(cfg, shapes)
    _check_tree(specs, shapes, name)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_cache_and_batch_specs_divisible(name):
    # pure-spec validation against both production meshes' axis sizes
    from repro.distributed import sharding as sh

    class FakeMesh:
        def __init__(self, axes):
            self.axis_names = tuple(axes)
            self.shape = {a: AXIS_SIZES[a] for a in axes}

    cfg = ARCHS[name]
    for axes in (("data", "model"), ("pod", "data", "model")):
        mesh = FakeMesh(axes)
        for shape in SHAPES:
            if not cell_is_runnable(cfg, shape):
                continue
            bs = batch_specs(cfg, shape)
            bp = sh.batch_pspecs(cfg, shape, mesh)
            _check_tree(
                {k: bp[k] for k in bs}, bs, f"{name}/{shape.name}/batch"
            )
            if shape.kind == "decode":
                ds = decode_input_specs(cfg, shape)
                cp = sh.cache_pspecs(cfg, shape, mesh, ds["cache"])
                _check_tree(cp, ds["cache"], f"{name}/{shape.name}/cache")


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_padded_heads_divide_tp(name):
    cfg = ARCHS[name]
    assert cfg.padded_n_heads % 16 == 0 or cfg.padded_n_heads == cfg.n_heads
    assert cfg.padded_n_heads % cfg.n_kv_heads == 0
    assert cfg.padded_vocab() % 16 == 0


def test_skip_matrix_documented():
    runnable = sum(
        cell_is_runnable(cfg, s) for cfg in ARCHS.values() for s in SHAPES
    )
    assert runnable == 32  # 40 cells − 8 documented long_500k skips
    subq = [n for n, c in ARCHS.items() if c.subquadratic]
    assert sorted(subq) == ["rwkv6-1.6b", "zamba2-2.7b"]
