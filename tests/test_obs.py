"""The unified telemetry layer (``repro.obs``).

Contracts under test:

* the event bus — begin/end spans, orphan ends raise, counter tracks,
  the zero-overhead disable switch;
* the metrics registry — Prometheus trio semantics and both exporters;
* efficiency — p̂(t) folding, the Theorem-6 fluid ratio (== 1.0 within
  1e-9 on the zero-noise single-tree case), L2 deviation, α residuals,
  device utilization;
* the one chrome-trace emitter — both legacy ``to_trace`` wrappers emit
  exactly the canonical slice key set, ``from_bus`` adds lanes/phases/
  counters and stays JSON-serializable;
* executor integration — an async run publishes well-formed spans whose
  aggregates match the ExecutionReport, and ``obs.disable()`` leaves
  the factors bit-identical while recording nothing;
* the dashboard — HTTP routes and the static HTML report.
"""
import json
import math
import urllib.request

import numpy as np
import pytest

from repro import obs
from repro.api import DeviceMesh, Problem, Session, SharedMemory
from repro.core.pm import tree_equivalent_lengths
from repro.core.trees import random_assembly_tree
from repro.obs.trace import PHASE_ORDER, SLICE_KEYS
from repro.sparse import (
    grid_laplacian_2d,
    nested_dissection_2d,
)

ALPHA = 0.9


@pytest.fixture(autouse=True)
def fresh_obs():
    obs.enable()
    obs.reset()
    yield
    obs.enable()
    obs.reset()


def grid_problem(g: int = 9) -> Problem:
    a = grid_laplacian_2d(g)
    return Problem.from_matrix(
        a, ALPHA, ordering=nested_dissection_2d(g), name=f"grid{g}"
    )


# ----------------------------------------------------------------------
# Event bus
# ----------------------------------------------------------------------
def test_bus_begin_end_round_trip():
    bus = obs.EventBus()
    sid = bus.begin("run", cat="front", key=3, device=2, t=1.0, flops=5.0)
    assert bus.open_spans() == [sid]
    sp = bus.end(sid, t=2.5, batched=2)
    assert bus.open_spans() == []
    assert (sp.name, sp.cat, sp.key, sp.device) == ("run", "front", 3, 2)
    assert sp.t0 == 1.0 and sp.t1 == 2.5 and sp.duration == 1.5
    assert sp.attrs == {"flops": 5.0, "batched": 2}
    assert bus.spans(cat="front", name="run") == [sp]


def test_bus_orphan_end_raises():
    bus = obs.EventBus()
    with pytest.raises(KeyError):
        bus.end(999)


def test_bus_disabled_publishes_nothing():
    bus = obs.EventBus()
    obs.disable()
    try:
        sid = bus.begin("run")
        assert sid == -1
        assert bus.end(sid) is None  # the disabled handshake is silent
        bus.span("run", 0.0, 1.0)
        bus.point("queue_depth", 4.0)
        assert len(bus) == 0 and bus.open_spans() == []
    finally:
        obs.enable()


def test_bus_counter_tracks_sorted_by_time():
    bus = obs.EventBus()
    bus.point("queue_depth", 2.0, t=5.0)
    bus.point("queue_depth", 3.0, t=1.0)
    bus.point("marker", t=2.0)  # value-less: not a counter sample
    tracks = bus.counter_tracks()
    assert tracks == {"queue_depth": [(1.0, 3.0), (5.0, 2.0)]}


def test_bus_subscribe_streams_and_unsubscribes():
    bus = obs.EventBus()
    seen = []
    unsub = bus.subscribe(seen.append)
    bus.span("run", 0.0, 1.0)
    bus.point("capacity", 8.0, t=0.5)
    assert [type(x).__name__ for x in seen] == ["Span", "Event"]
    unsub()
    bus.span("run", 1.0, 2.0)
    assert len(seen) == 2


def test_bus_mixed_clocks_are_tagged():
    bus = obs.EventBus()
    bus.span("run", 0.0, 1.0, clock=obs.VIRTUAL)
    bus.span("run", 0.0, 1.0, clock=obs.WALL)
    clocks = {s.clock for s in bus.spans()}
    assert clocks == {obs.VIRTUAL, obs.WALL}


# ----------------------------------------------------------------------
# Metrics registry
# ----------------------------------------------------------------------
def test_counter_labels_and_monotonicity():
    reg = obs.Registry()
    c = reg.counter("repro_requests_total", "requests", unit="1")
    c.inc()
    c.inc(2.0, tenant=3)
    c.inc(1.0, tenant=3)
    assert c.value == 1.0
    assert c.value_of(tenant=3) == 3.0
    with pytest.raises(ValueError):
        c.inc(-1.0)
    text = reg.prometheus()
    assert "# TYPE repro_requests_total counter" in text
    assert 'repro_requests_total{tenant="3"} 3' in text


def test_gauge_track_series():
    reg = obs.Registry()
    g = reg.gauge("repro_queue_depth", "depth", track=True)
    g.set(2.0, t=0.5)
    g.set(5.0, t=1.5)
    assert g.value == 5.0
    assert g.track() == [(0.5, 2.0), (1.5, 5.0)]


def test_histogram_prometheus_semantics():
    reg = obs.Registry()
    h = reg.histogram("repro_lat", "latency", unit="s", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, float("nan")):
        h.observe(v)
    assert h.count == 4  # NaN observations are dropped
    assert h.mean() == pytest.approx((0.05 + 0.5 + 0.5 + 5.0) / 4)
    assert h.quantile(0.5) == 1.0  # bucket-resolved upper bound
    lines = h.prometheus()
    # cumulative bucket counts, then sum and count
    assert 'repro_lat_bucket{le="0.1"} 1' in lines
    assert 'repro_lat_bucket{le="1"} 3' in lines
    assert 'repro_lat_bucket{le="+Inf"} 4' in lines
    assert any(l.startswith("repro_lat_sum ") for l in lines)
    assert "repro_lat_count 4" in lines


def test_registry_kind_conflict_and_snapshot():
    reg = obs.Registry()
    reg.counter("repro_x", "a counter").inc()
    with pytest.raises(TypeError):
        reg.gauge("repro_x")
    snap = reg.snapshot()
    json.dumps(snap)  # JSON-safe by contract
    assert snap["repro_x"]["values"]["total"] == 1.0


def test_disabled_registry_records_nothing():
    reg = obs.Registry()
    obs.disable()
    try:
        reg.counter("repro_c").inc()
        reg.gauge("repro_g", track=True).set(3.0)
        reg.histogram("repro_h").observe(1.0)
        assert reg.counter("repro_c").value == 0.0
        assert reg.gauge("repro_g").value == 0.0
        assert reg.histogram("repro_h").count == 0
    finally:
        obs.enable()


# ----------------------------------------------------------------------
# Efficiency: p̂(t), the fluid bound, α residuals, utilization
# ----------------------------------------------------------------------
def test_fold_share_timeline():
    steps = obs.fold_share_timeline(
        [(0.0, 2.0, 4.0), (1.0, 3.0, 2.0), (5.0, 5.0, 9.0)]
    )
    assert steps == [(0.0, 4.0), (1.0, 6.0), (2.0, 2.0), (3.0, 0.0)]


def test_l2_deviation_zero_iff_identical():
    ref = obs.pm_reference_timeline(8.0, 10.0)
    assert obs.l2_share_deviation(ref, ref) == 0.0
    half = [(0.0, 4.0), (20.0, 0.0)]  # half the share, twice as long
    dev = obs.l2_share_deviation(half, ref)
    assert dev > 0.3


def test_schedule_l2_deviation_fluid_pm_is_zero(rng):
    tree = random_assembly_tree(60, rng)
    sched = Session(SharedMemory(16)).load(tree, ALPHA).plan("pm").schedule
    # the fluid PM schedule engages the full pool until its own fluid
    # makespan — exactly the Theorem-6 reference profile
    assert obs.schedule_l2_deviation(sched) == pytest.approx(0.0, abs=1e-6)


def test_fluid_ratio_zero_noise_single_tree(rng):
    """Acceptance: fluid_ratio == 1.0 within 1e-9 on the zero-noise
    single-tree case (the online PM loop *is* the fluid optimum)."""
    tree = random_assembly_tree(80, rng)
    rep = Session(SharedMemory(24)).load(tree, ALPHA).simulate(policy="pm")
    assert abs(obs.fluid_ratio(rep) - 1.0) < 1e-9
    assert abs(rep.metrics["fluid_ratio"] - 1.0) < 1e-9
    fluid = tree_equivalent_lengths(tree, ALPHA)[tree.root] / 24**ALPHA
    assert obs.fluid_ratio(rep.makespan, fluid) == pytest.approx(1.0, abs=1e-9)


def test_alpha_residuals_recover_perfect_model():
    pts = [
        ("64x32", g, 3.0 * g**ALPHA)
        for g in (1, 2, 4, 8)
    ] + [("128x64", g, 7.0 * g**ALPHA) for g in (2, 8)]
    out = obs.alpha_residuals(pts, ALPHA)
    for bucket in ("64x32", "128x64"):
        assert out[bucket]["rms"] == pytest.approx(0.0, abs=1e-12)
        assert out[bucket]["alpha_fit"] == pytest.approx(ALPHA, abs=1e-12)


def test_device_utilization_merges_overlaps():
    mk = lambda sid, t0, t1, dev, used: obs.Span(
        sid, "run", "front", sid, dev, t0, t1, attrs={"devices_used": used}
    )
    spans = [
        mk(0, 0.0, 1.0, 0, 2),  # lanes 0,1
        mk(1, 0.5, 1.0, 0, 2),  # batched twin: same lanes, overlap merged
        mk(2, 1.0, 2.0, 2, 1),  # lane 2
    ]
    u = obs.device_utilization(spans, 4, horizon=2.0)
    assert u["per_device"] == pytest.approx([0.5, 0.5, 0.5, 0.0])
    assert u["occupancy"] == pytest.approx(0.375)
    assert u["horizon"] == 2.0


# ----------------------------------------------------------------------
# One trace vocabulary: both legacy emitters, plus the bus view
# ----------------------------------------------------------------------
def test_schedule_trace_key_set_regression():
    prob = grid_problem(9)
    sched = Session(SharedMemory(8)).load(prob).plan("greedy").schedule
    trace = sched.to_trace()
    assert trace
    for ev in trace:
        assert set(ev) == SLICE_KEYS
        assert ev["ph"] == "X"


@pytest.fixture(scope="module")
def async_run():
    """One instrumented async execution, captured before any reset."""
    obs.enable()
    obs.reset()
    rep = (
        Session(DeviceMesh(plan_devices=8))
        .load(grid_problem(9))
        .plan("greedy")
        .execute(mode="async", warmup=False)
    )
    reg = obs.get_registry()
    return {
        "rep": rep,
        "spans": obs.BUS.spans(),
        "open": obs.BUS.open_spans(),
        "tracks": obs.BUS.counter_tracks(),
        "snapshot": reg.snapshot(),
        "bus_trace": obs.from_bus(obs.BUS),
        "report_trace": rep.detail.to_trace(),
    }


def test_execution_trace_key_set_regression(async_run):
    trace = async_run["report_trace"]
    assert trace
    for ev in trace:
        assert set(ev) == SLICE_KEYS
        assert ev["ph"] == "X"


def test_async_run_spans_well_formed(async_run):
    spans = async_run["spans"]
    assert async_run["open"] == []  # every begin() was matched
    fronts = [s for s in spans if s.cat == "front"]
    assert fronts and {s.name for s in fronts} <= set(PHASE_ORDER)
    by_key = {}
    for s in fronts:
        by_key.setdefault(s.key, {})[s.name] = s
    n_run = 0
    for key, phases in by_key.items():
        run = phases.get("run")
        assert run is not None, f"front {key} has no run span"
        n_run += 1
        assert math.isfinite(run.t0) and run.t1 >= run.t0 >= 0.0
        assert run.attrs["devices_used"] >= 1
        if "submit" in phases:  # submit ends where the run starts
            assert phases["submit"].t1 == pytest.approx(run.t0, abs=1e-9)
        if "ready" in phases:  # ready ends at (or before) dispatch
            assert phases["ready"].t1 <= run.t0 + 1e-9
    rep = async_run["rep"]
    assert n_run == len(rep.detail.trace)


def test_async_run_counters_match_report(async_run):
    rep, snap = async_run["rep"], async_run["snapshot"]
    trace = rep.detail.trace
    assert snap["repro_fronts_completed_total"]["values"]["total"] == len(trace)
    assert (
        snap["repro_dispatches_total"]["values"]["total"]
        == rep.detail.n_dispatches
    )
    n_ready = sum(1 for e in trace if not math.isnan(e.t_ready))
    assert snap["repro_ready_latency_seconds"]["count"] == n_ready
    # batch widths: one sample per dispatch interval, fronts sum to trace
    widths = snap["repro_batch_width"]
    assert widths["sum"] == len(trace)
    assert snap["repro_peak_resident_bytes"]["values"]["value"] == (
        rep.detail.measured_peak_bytes
    )


def test_async_run_live_counter_tracks(async_run):
    tracks = async_run["tracks"]
    for name in ("queue_depth", "resident_bytes"):
        assert name in tracks and tracks[name]
        ts = [t for t, _ in tracks[name]]
        assert ts == sorted(ts)
    assert all(v >= 0 for _, v in tracks["resident_bytes"])


def test_bus_trace_has_lanes_phases_and_counters(async_run):
    events = async_run["bus_trace"]
    json.dumps(events)  # perfetto-loadable JSON
    phs = {e["ph"] for e in events}
    assert phs == {"M", "X", "C"}
    # metadata first, naming host + device lanes
    metas = [e for e in events if e["ph"] == "M"]
    assert events[: len(metas)] == metas
    names = {e["args"]["process_name"] for e in metas}
    assert "host" in names
    assert any(n.startswith("device") for n in names)
    for e in events:
        if e["ph"] == "X":
            assert set(e) == SLICE_KEYS and e["dur"] > 0
    counters = {e["name"] for e in events if e["ph"] == "C"}
    assert "queue_depth" in counters and "resident_bytes" in counters


def test_run_report_metrics_have_no_null_values(async_run):
    for k, v in async_run["rep"].metrics.items():
        assert v is not None, k
        assert not (isinstance(v, float) and math.isnan(v)), k


def test_utilization_and_efficiency_from_bus(async_run):
    spans = async_run["spans"]
    u = obs.device_utilization(
        [s for s in spans if s.cat == "front"], 8
    )
    assert 0.0 < u["occupancy"] <= 1.0
    assert len(u["per_device"]) == 8
    summary = obs.efficiency_summary(async_run["rep"])
    assert summary["fluid_ratio"] >= 0.0
    json.dumps(summary)


# ----------------------------------------------------------------------
# Zero-overhead disable: bit-identical factors, silent instruments
# ----------------------------------------------------------------------
def test_disable_leaves_factors_bit_identical():
    prob = grid_problem(7)

    def run():
        obs.reset()
        rep = (
            Session(DeviceMesh(plan_devices=4))
            .load(prob)
            .plan("greedy")
            .execute(mode="async", warmup=False)
        )
        return rep.artifact.to_dense_l()

    on = run()
    obs.disable()
    try:
        off = run()
        assert len(obs.BUS) == 0
        assert obs.get_registry().names() == []
    finally:
        obs.enable()
    np.testing.assert_allclose(on, off, rtol=0, atol=0)


# ----------------------------------------------------------------------
# Online / serve integration: virtual-clock spans
# ----------------------------------------------------------------------
def test_serve_publishes_virtual_spans_and_admission_metrics(rng):
    t1 = random_assembly_tree(30, rng)
    t2 = random_assembly_tree(40, rng)
    p1 = Problem.from_tree(t1, ALPHA, name="t1")
    p2 = Problem.from_tree(t2, ALPHA, name="t2")
    rep = Session(SharedMemory(8)).serve(
        [(p1, 0.0, 0), (p2, 0.1, 1)], admission="fair", max_concurrent=1
    )
    trees = obs.BUS.spans(cat="tree", name="run")
    assert len(trees) == 2
    assert all(s.clock == obs.VIRTUAL for s in trees)
    tasks = obs.BUS.spans(cat="task", name="run")
    assert len(tasks) == t1.n + t2.n
    reg = obs.get_registry()
    admit = reg.counter("repro_admission_requests_total")
    assert admit.value_of(tenant=0) == 1.0
    assert admit.value_of(tenant=1) == 1.0
    assert reg.histogram("repro_admission_wait_seconds").count == 2
    assert 0.0 < reg.gauge("repro_online_utilization").value <= 1.0
    # virtual-clock capacity samples ride next to the wall-clock ones
    assert "capacity" in obs.BUS.counter_tracks()
    assert rep.metrics["fluid_ratio"] >= 1.0 - 1e-12


def test_elastic_run_publishes_plan_segments():
    from repro.core.trees import balanced_tree
    from repro.runtime.elastic import ElasticEvent, run_elastic_schedule

    tree = balanced_tree(depth=4, arity=2)
    mk, plans = run_elastic_schedule(
        tree, ALPHA, 8, [ElasticEvent(time=0.05, devices=4)]
    )
    segs = obs.BUS.spans(cat="plan", name="run")
    assert len(segs) == len(plans)
    assert all(s.clock == obs.VIRTUAL for s in segs)
    assert segs[-1].t1 == pytest.approx(mk)
    reg = obs.get_registry()
    assert reg.counter("repro_elastic_replans_total").value == len(plans)


# ----------------------------------------------------------------------
# Dashboard: HTTP routes, static HTML, trace file
# ----------------------------------------------------------------------
def _get(url: str) -> bytes:
    with urllib.request.urlopen(url, timeout=10) as resp:
        assert resp.status == 200
        return resp.read()


def test_dashboard_routes(rng):
    tree = random_assembly_tree(40, rng)
    Session(SharedMemory(8)).load(tree, ALPHA).simulate(policy="pm")
    dash = obs.Dashboard(0, context={"subtitle": "test run"})
    try:
        page = _get(dash.url).decode()
        assert "<html" in page and "test run" in page
        prom = _get(dash.url + "metrics").decode()
        assert "# TYPE" in prom
        snap = json.loads(_get(dash.url + "metrics.json"))
        assert isinstance(snap, dict)
        trace = json.loads(_get(dash.url + "trace.json"))
        assert trace["traceEvents"]
        with pytest.raises(urllib.error.HTTPError):
            _get(dash.url + "nope")
    finally:
        dash.stop()


def test_serve_dashboard_port_lifecycle(rng):
    tree = random_assembly_tree(30, rng)
    sess = Session(SharedMemory(8))
    rep = sess.serve(
        [(Problem.from_tree(tree, ALPHA), 0.0)], dashboard_port=0
    )
    assert sess.dashboard is not None
    try:
        page = _get(sess.dashboard.url).decode()
        assert "<html" in page
        # post-run context carries the run's makespan
        assert sess.dashboard.context["makespan"] == rep.makespan
    finally:
        sess.dashboard.stop()


def test_save_html_and_trace_files(tmp_path, rng):
    tree = random_assembly_tree(40, rng)
    rep = Session(SharedMemory(8)).load(tree, ALPHA).simulate(policy="pm")
    html_path = rep.save_html(tmp_path / "run.html")
    doc = open(html_path).read()
    assert "<html" in doc and "repro" in doc
    trace_path = tmp_path / "run.trace.json"
    obs.save_trace(obs.from_bus(obs.BUS), trace_path)
    loaded = json.loads(open(trace_path).read())
    assert loaded["displayTimeUnit"] == "ms"
    assert loaded["traceEvents"]
