"""The dry-run driver itself, end to end, in a subprocess (it must own the
XLA device-forging flag before jax initializes — hence not in-process)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_cell(arch, shape, multi_pod=False):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape]
    if multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    last = [l for l in out.stdout.splitlines() if l.startswith("{")][-1]
    return json.loads(last)


@pytest.mark.slow
def test_dryrun_decode_cell_single_pod():
    d = _run_cell("rwkv6-1.6b", "decode_32k")
    assert d["status"] == "ok"
    assert d["chips"] == 256
    assert d["peak_bytes_tpu_est"] < 16e9
    assert d["hlo_flops"] > 0 and d["hlo_bytes"] > 0
    assert d["bottleneck"] in ("t_compute", "t_memory", "t_collective")


@pytest.mark.slow
def test_dryrun_train_cell_multi_pod():
    d = _run_cell("qwen2.5-3b", "train_4k", multi_pod=True)
    assert d["status"] == "ok"
    assert d["chips"] == 512
    assert d["peak_bytes_tpu_est"] < 16e9
    assert d["model_hlo_ratio"] > 0.2  # sane useful-flops fraction


@pytest.mark.slow
def test_dryrun_skip_cell():
    d = _run_cell("qwen3-4b", "long_500k")
    assert d["status"] == "skipped"
