"""Step-profile properties (§4 p(t)) — the elastic-capacity foundation."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; skip if absent
from hypothesis import given, strategies as st

from repro.core import Profile


@st.composite
def profiles(draw):
    n = draw(st.integers(1, 5))
    steps = [
        (draw(st.floats(0.1, 5.0)), draw(st.floats(0.5, 64.0)))
        for _ in range(n)
    ]
    return Profile.of(steps)


@given(profiles(), st.floats(0.55, 1.0), st.floats(0.01, 40.0))
def test_work_time_inversion_roundtrip(prof, alpha, t):
    w = prof.work_until(t, alpha)
    assert prof.time_for_work(w, alpha) == pytest.approx(t, rel=1e-9, abs=1e-9)


@given(profiles(), st.floats(0.55, 1.0), st.floats(0.0, 10.0), st.floats(0.0, 10.0))
def test_work_is_monotone_and_additive(prof, alpha, t1, dt):
    w1 = prof.work_until(t1, alpha)
    w2 = prof.work_until(t1 + dt, alpha)
    assert w2 >= w1 - 1e-12
    # restriction after t1 carries the remaining work
    rest = prof.restricted_after(t1)
    assert rest.work_until(dt, alpha) == pytest.approx(w2 - w1, rel=1e-6, abs=1e-9)


@given(profiles(), st.floats(0.55, 1.0), st.floats(1.1, 4.0))
def test_scaling_speeds_up(prof, alpha, f):
    big = prof.scaled(f)
    w = prof.work_until(3.0, alpha)
    if w > 1e-9:
        assert big.time_for_work(w, alpha) <= prof.time_for_work(w, alpha) + 1e-9
