"""Core memory model: resident-bytes timelines, Liu's traversal, and the
budget-bounded PM schedule (arXiv:1210.2580 / 1410.0329 adaptations)."""
import math

import numpy as np
import pytest

from repro.core.graph import TaskTree
from repro.core.memory import (
    Footprints,
    footprints_from_fronts,
    memory_timeline,
    pm_bounded_schedule,
    pm_peak,
    sequential_peak,
    sequential_traversal,
    zero_footprints,
)
from repro.core.pm import tree_equivalent_lengths
from repro.core.profiles import Profile
from repro.core.trees import random_assembly_tree

ALPHA = 0.9


def random_footprints(n: int, rng) -> Footprints:
    front = rng.uniform(4.0, 40.0, n)
    nbfrac = rng.uniform(0.2, 0.9, n)
    factor = front * nbfrac * 0.5
    cb = front * (1 - nbfrac) ** 2
    return Footprints(front, factor, cb)


# ----------------------------------------------------------------------
# Timeline semantics
# ----------------------------------------------------------------------
def test_timeline_hand_example():
    """Two leaves into a root: fronts, factors, CBs and the extend-add
    transient, checked by hand."""
    tree = TaskTree(parent=np.array([-1, 0, 0]), lengths=np.ones(3))
    fp = Footprints(
        front_bytes=np.array([10.0, 4.0, 6.0]),
        factor_bytes=np.array([3.0, 1.0, 2.0]),
        cb_bytes=np.array([0.0, 2.0, 3.0]),
    )
    spans = {1: (0.0, 1.0), 2: (0.0, 2.0), 0: (2.0, 3.0)}
    tl = memory_timeline(tree.parent, spans, fp)
    assert tl.usage_at(0.5) == 10.0  # both leaf fronts
    assert tl.usage_at(1.5) == 9.0  # leaf 1 → factor+CB, leaf 2 front
    # at t=2 the root's front coexists with both CBs before consuming
    # them: 3 (factor1+cb1) + 5 (factor2+cb2) + 10 (root front) = 18
    assert tl.peak == 18.0
    assert tl.usage_at(2.5) == 13.0  # CBs consumed
    assert tl.usage_at(3.5) == 6.0  # factors remain
    assert tl.node_peaks == {0: 18.0}


def test_timeline_invariant_under_reparameterization(rng):
    """The peak only depends on span interleaving, not durations —
    work-time and wall-clock spans agree."""
    tree = random_assembly_tree(40, rng)
    fp = random_footprints(tree.n, rng)
    order = tree.topo_order()
    spans = {int(t): (float(k), float(k + 1)) for k, t in enumerate(order)}
    warped = {
        t: (math.sqrt(1 + a) - 1, math.sqrt(1 + b) - 1)
        for t, (a, b) in spans.items()
    }
    a = memory_timeline(tree.parent, spans, fp)
    b = memory_timeline(tree.parent, warped, fp)
    assert a.peak == pytest.approx(b.peak, rel=1e-12)


def test_empty_and_zero_footprints(rng):
    tree = random_assembly_tree(10, rng)
    assert memory_timeline(tree.parent, {}, zero_footprints(tree.n)).peak == 0.0
    spans = {i: (0.0, 1.0) for i in range(tree.n)}
    assert (
        memory_timeline(tree.parent, spans, zero_footprints(tree.n)).peak == 0.0
    )


def test_footprints_helpers():
    fp = footprints_from_fronts([4, 10], [4, 3], itemsize=8)
    assert fp.front_bytes.tolist() == [128.0, 800.0]  # m² · 8
    assert fp.factor_bytes.tolist() == [128.0, 240.0]  # m·nb · 8
    assert fp.cb_bytes.tolist() == [0.0, 392.0]  # (m−nb)² · 8
    assert fp.padded(3).n == 3 and fp.padded(3).front_bytes[2] == 0.0
    assert fp.take([1]).front_bytes.tolist() == [800.0]
    with pytest.raises(ValueError):
        fp.padded(1)
    with pytest.raises(ValueError):
        Footprints(np.array([1.0]), np.array([-1.0]), np.array([0.0]))


# ----------------------------------------------------------------------
# Liu's sequential traversal
# ----------------------------------------------------------------------
def _postorder_spans(tree, seq):
    """Unit-time sequential spans following the traversal's child order."""
    order = []
    stack = [(tree.root, False)]
    ch_order = seq.child_order
    while stack:
        node, expanded = stack.pop()
        if expanded:
            order.append(node)
        else:
            stack.append((node, True))
            for c in reversed(ch_order[node]):
                stack.append((c, False))
    return {int(t): (float(k), float(k + 1)) for k, t in enumerate(order)}


def test_liu_traversal_matches_its_own_timeline(rng):
    """The analytic peak equals the timeline of actually executing the
    traversal one task at a time."""
    for _ in range(5):
        tree = random_assembly_tree(int(rng.integers(10, 80)), rng)
        fp = random_footprints(tree.n, rng)
        seq = sequential_traversal(tree, fp)
        tl = memory_timeline(tree.parent, _postorder_spans(tree, seq), fp)
        assert tl.peak == pytest.approx(seq.min_peak(tree.root), rel=1e-12)


def test_liu_order_beats_random_postorders(rng):
    """No randomly shuffled postorder does better than Liu's order."""
    for _ in range(3):
        tree = random_assembly_tree(30, rng)
        fp = random_footprints(tree.n, rng)
        best = sequential_peak(tree, fp)
        ch = tree.children_lists()
        for _ in range(20):
            order = []
            stack = [(tree.root, False)]
            while stack:
                node, expanded = stack.pop()
                if expanded:
                    order.append(node)
                else:
                    stack.append((node, True))
                    kids = list(ch[node])
                    rng.shuffle(kids)
                    for c in kids:
                        stack.append((c, False))
            spans = {
                int(t): (float(k), float(k + 1)) for k, t in enumerate(order)
            }
            tl = memory_timeline(tree.parent, spans, fp)
            assert tl.peak >= best * (1 - 1e-12)


def test_pm_peak_at_least_sequential_min(rng):
    """Parallelism never undercuts the sequential bound."""
    for _ in range(5):
        tree = random_assembly_tree(int(rng.integers(10, 120)), rng)
        fp = random_footprints(tree.n, rng)
        assert pm_peak(tree, ALPHA, fp) >= sequential_peak(tree, fp) * (
            1 - 1e-9
        )


# ----------------------------------------------------------------------
# Budget-bounded PM
# ----------------------------------------------------------------------
def test_pm_bounded_budget_sweep(rng):
    """Across the whole feasible range: §4-valid, within budget, and
    makespan degrades monotonically as the budget tightens."""
    tree = random_assembly_tree(60, rng)
    fp = random_footprints(tree.n, rng)
    p = 16.0
    lo = sequential_peak(tree, fp)
    hi = max(pm_peak(tree, ALPHA, fp), lo * 1.01)
    prev_makespan = None
    for frac in (1.0, 0.7, 0.4, 0.1, 0.0):
        budget = lo + frac * (hi - lo)
        es, info = pm_bounded_schedule(tree, ALPHA, p, fp, budget)
        es.validate(tree, Profile.constant(p))
        spans = {
            i: (es.start_time(i), es.completion_time(i))
            for i in range(tree.n)
            if es.pieces.get(i)
        }
        tl = memory_timeline(tree.parent, spans, fp)
        assert tl.peak <= budget * (1 + 1e-9)
        mk = es.makespan()
        if prev_makespan is not None:
            assert mk >= prev_makespan * (1 - 1e-9)
        prev_makespan = mk
    # the fluid optimum is recovered at infinite budget
    es, info = pm_bounded_schedule(tree, ALPHA, p, fp, math.inf)
    fluid = tree_equivalent_lengths(tree, ALPHA)[tree.root] / p**ALPHA
    assert es.makespan() == pytest.approx(fluid, rel=1e-12)
    assert info["segments"] == 1


def test_pm_bounded_respects_budget_with_heavy_outputs(rng):
    """Generic footprints with factor+CB > front (a task whose output
    outweighs its working set): the budget must hold for the
    post-completion residency too, not just the transient."""
    for _ in range(3):
        tree = random_assembly_tree(40, rng)
        n = tree.n
        fp = Footprints(
            np.full(n, 1.0),
            rng.uniform(5.0, 15.0, n),  # outputs dwarf the fronts
            rng.uniform(0.0, 3.0, n),
        )
        lo = sequential_peak(tree, fp)
        # all factors stay resident, so the sequential minimum is at
        # least the total retained bytes
        assert lo >= fp.total_factor()
        for frac in (1.0, 0.3, 0.0):
            hi = max(pm_peak(tree, ALPHA, fp), lo * 1.01)
            budget = lo + frac * (hi - lo)
            es, _ = pm_bounded_schedule(tree, ALPHA, 8.0, fp, budget)
            spans = {
                i: (es.start_time(i), es.completion_time(i))
                for i in range(tree.n)
                if es.pieces.get(i)
            }
            tl = memory_timeline(tree.parent, spans, fp)
            assert tl.peak <= budget * (1 + 1e-9)


def test_pm_bounded_infeasible_budget_raises(rng):
    tree = random_assembly_tree(25, rng)
    fp = random_footprints(tree.n, rng)
    with pytest.raises(ValueError):
        pm_bounded_schedule(
            tree, ALPHA, 8.0, fp, 0.5 * sequential_peak(tree, fp)
        )


def test_timeline_json_roundtrip(rng):
    from repro.core.memory import MemoryTimeline

    tree = random_assembly_tree(20, rng)
    fp = random_footprints(tree.n, rng)
    order = tree.topo_order()
    spans = {int(t): (float(k), float(k + 1)) for k, t in enumerate(order)}
    tl = memory_timeline(tree.parent, spans, fp, budget=123.0)
    rt = MemoryTimeline.from_dict(tl.to_dict())
    assert rt.peak == tl.peak and rt.budget == 123.0
    assert rt.steps == tl.steps and rt.node_peaks == tl.node_peaks
    inf_tl = memory_timeline(tree.parent, spans, fp)
    assert MemoryTimeline.from_dict(inf_tl.to_dict()).budget == math.inf
