"""Fault tolerance (PM-elastic), stragglers, two-pod placement."""
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import Profile, random_assembly_tree, tree_equivalent_lengths
from repro.runtime import (
    ElasticController,
    ElasticEvent,
    HeartbeatMonitor,
    StragglerDetector,
    rebalance_two_pods,
    run_elastic_schedule,
)
from repro.serve import Request, place_two_pods, place_two_pods_equal


def test_heartbeat_detects_failure():
    hb = HeartbeatMonitor(n_nodes=4, timeout=2.0)
    for t in (0.0, 1.0, 2.0):
        for n in range(4):
            if not (n == 2 and t > 0.5):
                hb.beat(n, t)
    assert hb.dead(3.0) == [2]
    assert 2 not in hb.alive(3.0)


def test_elastic_profile_and_invariance(rng):
    """p(t) from capacity events; the PM makespan under the profile equals
    the work-time inversion of Theorem 6 — ratio invariance in action."""
    tree = random_assembly_tree(80, rng)
    alpha = 0.9
    ctl = ElasticController(initial_devices=64)
    ctl.capacity_change(1.0, 48)  # lose a node
    ctl.capacity_change(3.0, 64)  # it rejoins
    prof = ctl.profile()
    assert prof.p_at(0.5) == 64 and prof.p_at(2.0) == 48 and prof.p_at(5.0) == 64
    eq = tree_equivalent_lengths(tree, alpha)[tree.root]
    assert ctl.pm_makespan(tree, alpha) == pytest.approx(
        prof.time_for_work(eq, alpha)
    )
    # losing capacity can only increase the makespan
    assert ctl.pm_makespan(tree, alpha) >= eq / 64**alpha - 1e-9


def test_run_elastic_schedule_converges(rng):
    tree = random_assembly_tree(60, rng)
    alpha = 0.85
    mk_plain, _ = run_elastic_schedule(tree, alpha, 64, [])
    mk_fail, plans = run_elastic_schedule(
        tree, alpha, 64, [ElasticEvent(time=mk_plain * 0.3, devices=32)]
    )
    assert mk_fail >= mk_plain - 1e-9
    assert len(plans) >= 2
    # fluid lower bound under the elastic profile
    prof = Profile.of([(mk_plain * 0.3, 64.0), (np.inf, 32.0)])
    eq = tree_equivalent_lengths(tree, alpha)[tree.root]
    assert mk_fail >= prof.time_for_work(eq, alpha) - 1e-9


def test_straggler_detection_and_rebalance(rng):
    det = StragglerDetector(n_nodes=4)
    for step in range(12):
        for n in range(4):
            det.record(n, 1.0 + (2.5 if n == 3 else 0.0) + rng.normal() * 0.01)
    assert det.stragglers() == [3]
    speeds = det.node_speeds()
    assert speeds[3] < 0.5
    res = rebalance_two_pods(
        rng.uniform(1, 5, size=8), pod_devices=256, speeds=(1.0, speeds[3]),
        alpha=0.9,
    )
    # the slow pod receives less x-work
    xs = np.asarray(rng.uniform(1, 5, size=0))
    assert len(res.on_p) + len(res.on_q) == 8
    assert len(res.on_p) >= len(res.on_q)


def test_two_pod_request_placement():
    cfg = ARCHS["qwen3-4b"]
    reqs = [Request(i, 1024 * (i + 1)) for i in range(6)]
    mk, placement = place_two_pods_equal(cfg, reqs, pod_devices=256, alpha=0.9)
    assert len(placement) == 6 and set(placement) <= {0, 1}
    assert mk > 0
    mk2, placement2 = place_two_pods(cfg, reqs, 256, 128, alpha=0.9, lam=1.05)
    assert len(placement2) == 6
    # degraded pod gets the smaller share of work
    w = np.array([r.prompt_tokens for r in reqs], float)
    assert w[np.array(placement2) == 1].sum() <= w.sum() * 0.6
