"""Amalgamation optimizer invariants (``repro.sparse.optimize``).

The rewrite pass may reshape the tree aggressively — what it must never
do is change the semantics the planner and executor rely on.  The
invariants pinned here:

* **partition** — provenance groups + culled nodes partition the
  original tree's indices exactly;
* **conservation** — total work is conserved (culled tasks carry zero
  length), and equivalent lengths are monotone: fusing tasks only
  *removes* parallelism, so ``orig.eq_root ≤ opt.eq_root ≤ total_work``
  (Definition 1: series-composition 𝓛 is the sum, parallel is smaller);
* **§4 validity** — PM and greedy plans of the optimized problem pass
  the resource / completeness / precedence predicates unchanged;
* **memory** — with a finite budget the optimized tree's certified
  sequential peak fits it, and ``plan(memory_budget=)`` certifies;
* **identity floor** — threshold 0 degrades to cull-only;
* **round-trip** — Provenance survives JSON.

Each invariant lives in a plain ``check_*`` helper so the seeded tests
below exercise them even when hypothesis is not installed; the
property-based suite at the bottom drives the same helpers over random
trees (shared "repro" profile from conftest).
"""
import json
import math

import numpy as np
import pytest

from repro.api.problem import Problem
from repro.core.memory import footprints_from_fronts, sequential_peak
from repro.core.trees import quotient_tree, random_assembly_tree
from repro.sparse.optimize import Provenance, optimize_problem

ALPHA = 0.9


# ----------------------------------------------------------------------
# invariant checkers (plain functions: shared by seeded + property tests)
# ----------------------------------------------------------------------
def check_partition(prob: Problem, opt: Problem) -> None:
    prov = opt.provenance
    assert prov is not None
    assert prov.n_original == prob.n
    cover = sorted(
        [m for g in prov.groups for m in g] + list(prov.culled)
    )
    assert cover == list(range(prob.n)), "provenance is not a partition"
    assert len(prov.groups) == opt.n
    # culled tasks carry no work
    assert all(prob.tree.lengths[c] == 0 for c in prov.culled)


def check_conservation(prob: Problem, opt: Problem) -> None:
    assert np.isclose(opt.total_work(), prob.total_work())
    # fusing replaces parallel composition by series composition, which
    # can only grow 𝓛 (Definition 1); series-only is the total work
    assert prob.eq_root <= opt.eq_root * (1 + 1e-9)
    assert opt.eq_root <= prob.total_work() * (1 + 1e-9)


def check_plans_valid(opt: Problem, p: int = 8) -> None:
    from repro.api import Session, SharedMemory

    for policy in ("pm", "greedy"):
        sess = Session(SharedMemory(p)).load(opt).plan(policy)
        sess.schedule.validate(opt)


def check_budget(prob: Problem, opt: Problem, budget: float) -> None:
    fp = opt.memory_footprints()
    assert fp is not None
    assert sequential_peak(opt.tree, fp) <= budget * (1 + 1e-9)


def check_roundtrip(opt: Problem) -> None:
    prov = opt.provenance
    rt = Provenance.from_dict(json.loads(json.dumps(prov.to_dict())))
    assert rt == prov


def random_problem(seed: int, n: int = 40, with_fp: bool = True) -> Problem:
    rng = np.random.default_rng(seed)
    tree = random_assembly_tree(n, rng)
    fp = None
    if with_fp:
        m = rng.integers(1, 24, size=n)
        nb = np.minimum(m, rng.integers(1, 8, size=n))
        fp = footprints_from_fronts(m, nb)
    return Problem.from_tree(tree, ALPHA, footprints=fp)


# ----------------------------------------------------------------------
# seeded deterministic coverage (runs with or without hypothesis)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2, 7])
def test_invariants_random_tree(seed):
    prob = random_problem(seed)
    opt = optimize_problem(prob)
    check_partition(prob, opt)
    check_conservation(prob, opt)
    check_plans_valid(opt)
    check_roundtrip(opt)
    assert opt.n <= prob.n


@pytest.mark.parametrize("seed", [0, 3])
def test_budget_backoff_certifies(seed):
    prob = random_problem(seed)
    orig_peak = prob.min_peak_memory()
    budget = orig_peak * 1.05
    opt = optimize_problem(prob, memory_budget=budget)
    check_partition(prob, opt)
    check_budget(prob, opt, budget)
    # and Session.plan certifies the optimized problem against it
    from repro.api import Session, SharedMemory

    sess = Session(SharedMemory(8)).load(opt)
    sess.plan("pm-bounded", memory_budget=budget)
    assert sess.schedule.memory is not None
    assert sess.schedule.memory.peak <= budget * (1 + 1e-9)


def test_infeasible_budget_raises():
    prob = random_problem(0)
    with pytest.raises(ValueError, match="sequential minimum"):
        optimize_problem(prob, memory_budget=prob.min_peak_memory() * 0.5)


def test_threshold_zero_is_cull_only():
    prob = random_problem(5)
    opt = optimize_problem(prob, max_front=0)
    prov = opt.provenance
    assert all(len(g) == 1 for g in prov.groups)
    # cull-only keeps the tree (and so the PM schedule) intact
    assert np.isclose(opt.eq_root, prob.eq_root)
    assert np.isclose(
        sequential_peak(opt.tree, opt.memory_footprints()),
        prob.min_peak_memory(),
    )


def test_cull_removes_degenerate_leaves():
    # a chain with a zero-length zero-footprint leaf hanging off it
    parent = np.array([-1, 0, 1, 1])
    lengths = np.array([3.0, 2.0, 1.0, 0.0])
    tree = __import__("repro.core.graph", fromlist=["TaskTree"]).TaskTree(
        parent=parent, lengths=lengths
    )
    m = np.array([4, 3, 2, 0])
    nb = np.array([4, 2, 1, 0])
    prob = Problem.from_tree(tree, ALPHA, footprints=footprints_from_fronts(m, nb))
    opt = optimize_problem(prob, max_front=0)
    assert opt.provenance.culled == (3,)
    assert opt.n == 3
    check_partition(prob, opt)
    check_conservation(prob, opt)


def test_double_optimize_rejected():
    opt = optimize_problem(random_problem(0))
    with pytest.raises(ValueError, match="provenance"):
        optimize_problem(opt)


def test_quotient_tree_rejects_non_tree_contractions():
    from repro.core.graph import TaskTree

    #      0
    #     / \
    #    1   2
    #   /     \
    #  3       4
    tree = TaskTree(
        parent=np.array([-1, 0, 0, 1, 2]), lengths=np.ones(5)
    )
    # {3, 4} has edges into both {1} and {2}: not a tree
    with pytest.raises(ValueError, match="not a tree"):
        quotient_tree(tree, [[0], [1], [2], [3, 4]])
    # double assignment
    with pytest.raises(ValueError, match="twice"):
        quotient_tree(tree, [[0, 1], [1, 2], [3], [4]])
    # non-coverage
    with pytest.raises(ValueError, match="cover"):
        quotient_tree(tree, [[0], [1], [2], [3]])
    # retained node under a culled one
    with pytest.raises(ValueError, match="culled"):
        quotient_tree(tree, [[0], [2], [3], [4]], culled=[1])
    # a valid contraction, for contrast
    q = quotient_tree(tree, [[0], [1, 3], [2, 4]])
    assert q.n == 3
    assert list(q.parent) == [-1, 0, 0]
    assert list(q.lengths) == [1.0, 2.0, 2.0]


def test_sparse_problem_counts_and_bits():
    """Dispatch-level fusion on a real matrix: fewer tasks, same factors."""
    import jax

    from repro.sparse import grid_laplacian_2d, nested_dissection_2d

    jax.config.update("jax_enable_x64", True)
    try:
        g = 9
        a = grid_laplacian_2d(g)
        prob = Problem.from_matrix(
            a, ALPHA, ordering=nested_dissection_2d(g), relax=0
        )
        opt = optimize_problem(prob, max_front=64)
        assert opt.n < prob.n
        check_partition(prob, opt)
        check_conservation(prob, opt)
        check_plans_valid(opt)
        # optimized execution lands factors in the original index space
        from repro.api import DeviceMesh, Session

        ref = (
            Session(DeviceMesh(plan_devices=8))
            .load(prob)
            .plan("greedy")
            .execute(warmup=False, mode="waves")
            .artifact.to_dense_l()
        )
        sess = Session(DeviceMesh(plan_devices=8)).load(opt).plan("greedy")
        assert "provenance" in sess.schedule.meta
        for mode in ("waves", "async"):
            l = sess.execute(warmup=False, mode=mode).artifact.to_dense_l()
            np.testing.assert_array_equal(ref, l)
    finally:
        jax.config.update("jax_enable_x64", False)


def test_session_optimize_chain():
    from repro.api import Session, SharedMemory

    prob = random_problem(2)
    sess = Session(SharedMemory(8)).load(prob).optimize()
    assert sess.problem.provenance is not None
    assert sess.schedule is None  # optimize invalidates any prior plan
    sess.plan("pm")
    assert sess.schedule.meta["provenance"]["n_original"] == prob.n


# The property-based half of this suite drives the same ``check_*``
# helpers over hypothesis-generated trees — see
# ``tests/test_optimize_props.py`` (kept separate so these seeded tests
# run even in a container without the hypothesis dev extra).
