"""Paper §6.1: two homogeneous nodes — Algorithm 11 and its invariants."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; skip if absent
from hypothesis import given, strategies as st

from repro.core import (
    TaskTree,
    hetero_exact,
    homogeneous_two_node,
    split_tree,
    star_tree,
    tree_equivalent_lengths,
    two_node_lower_bound,
)


@st.composite
def trees(draw, max_n=30):
    n = draw(st.integers(min_value=1, max_value=max_n))
    seed = draw(st.integers(min_value=0, max_value=2**31))
    rng = np.random.default_rng(seed)
    parent = np.full(n, -1, dtype=np.int64)
    for i in range(1, n):
        parent[i] = int(rng.integers(0, i))
    return TaskTree(parent=parent, lengths=rng.uniform(0.2, 10.0, size=n))


alphas = st.floats(min_value=0.6, max_value=0.95)


@given(trees(), alphas, st.floats(4.0, 64.0))
def test_alg11_basic_invariants(tree, alpha, p):
    res = homogeneous_two_node(tree, alpha, p)
    lb = two_node_lower_bound(tree, alpha, p)
    assert res.makespan >= lb - 1e-9 * lb
    # every task is placed on exactly one node
    placed = set(res.placement)
    assert placed == {int(l) for l in tree.labels if l >= 0}
    assert set(res.placement.values()) <= {0, 1}


@given(trees(), alphas, st.floats(4.0, 64.0))
def test_alg11_fluid_respects_proof_bound(tree, alpha, p):
    """Reproduction finding (recorded in DESIGN.md §Repro-notes): the
    paper's inductive step bounds the recursive makespan by
    (4/3)^α · Δ_{p,2}, where Δ_{p,2} is the *unrestricted* PM time of
    G_{p,2} on 2p — but when G_{p,2} degenerates to a chain no
    𝓡-respecting schedule can approach it, and the literal invariant
    M ≤ (4/3)^α · M_p fails (hypothesis finds such trees reliably).  The
    sound empirical invariant we assert: the algorithm never exceeds both
    the proof bound AND the single-node PM fallback — on every instance it
    is within (4/3)^α of a certified achievable schedule."""
    from repro.core.pm import tree_equivalent_lengths

    res = homogeneous_two_node(tree, alpha, p, snap=False)
    eq = tree_equivalent_lengths(tree, alpha)[tree.root]
    m_single = eq / p**alpha  # always 𝓡-feasible: everything on one node
    bound = max((4.0 / 3.0) ** alpha * res.m_p_lb, m_single)
    assert res.makespan <= bound * (1 + 1e-9)


@given(
    st.lists(st.floats(0.5, 20.0), min_size=2, max_size=10),
    alphas,
    st.floats(4.0, 32.0),
)
def test_alg11_vs_bruteforce_independent(lengths, alpha, p):
    """Independent tasks: the optimal two-node schedule is the optimal
    partition (each side runs PM); Algorithm 11 must be within (4/3)^α."""
    tree = star_tree(lengths)
    res = homogeneous_two_node(tree, alpha, p)
    opt, _ = hetero_exact(lengths, p, p, alpha)
    assert res.makespan <= (4.0 / 3.0) ** alpha * opt * (1 + 1e-9)
    assert res.makespan >= opt - 1e-9 * opt


def test_theorem7_partition_instance():
    """The NP-hardness gadget: L_i = a_i^α with Σa = 2p and a perfect
    partition ⇒ optimal makespan 1; Algorithm 11 stays within (4/3)^α."""
    alpha = 0.8
    a = [3.0, 1.0, 2.0, 2.0, 3.0, 1.0]  # perfect partition: 6 / 6
    p = sum(a) / 2.0 / 1.0  # 2p = Σa
    lengths = [x**alpha for x in a]
    tree = star_tree(lengths)
    res = homogeneous_two_node(tree, alpha, p / 1.0)
    # optimal = 1 when both halves sum to p... here 2 nodes of p = Σa/2
    opt, _ = hetero_exact(lengths, p, p, alpha)
    assert opt == pytest.approx((max(6.0, 6.0) / p) ** alpha, rel=1e-9)
    assert res.makespan <= (4.0 / 3.0) ** alpha * opt + 1e-9


def test_chain_tree_single_node():
    tree = TaskTree(parent=np.array([-1, 0, 1, 2]), lengths=np.ones(4))
    res = homogeneous_two_node(tree, 0.9, 8.0)
    assert res.makespan == pytest.approx(4.0 / 8.0**0.9)
    assert set(res.placement.values()) == {0}


# ----------------------------------------------------------------------
@given(trees(max_n=20), alphas, st.floats(0.05, 0.95))
def test_split_tree_conserves_equivalent_length_fluid(tree, alpha, frac):
    eq = tree_equivalent_lengths(tree, alpha)[tree.root]
    cut = frac * eq
    pre, suf = split_tree(tree, cut, alpha, snap=False)
    eq_pre = tree_equivalent_lengths(pre, alpha)[pre.root] if pre else 0.0
    eq_suf = tree_equivalent_lengths(suf, alpha)[suf.root] if suf else 0.0
    # fluid split is exact in equivalent length (work-time additivity)
    assert eq_pre + eq_suf == pytest.approx(eq, rel=1e-6)
    assert eq_suf == pytest.approx(cut, rel=1e-6)


@given(trees(max_n=20), alphas, st.floats(0.05, 0.95))
def test_split_tree_snap_conserves_work(tree, alpha, frac):
    eq = tree_equivalent_lengths(tree, alpha)[tree.root]
    pre, suf = split_tree(tree, frac * eq, alpha, snap=True)
    total = tree.lengths.sum()
    w_pre = pre.lengths.sum() if pre else 0.0
    w_suf = suf.lengths.sum() if suf else 0.0
    # snapped split never splits a task: total work is partitioned exactly
    assert w_pre + w_suf == pytest.approx(total, rel=1e-9)
