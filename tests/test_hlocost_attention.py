"""attention_scan_bytes must attribute exactly the attention-while subtree
(the flash-projection methodology's measurement side)."""
from repro.launch.hlocost import analyze, attention_scan_bytes

HLO = """
HloModule t

%attnbody (p: (s32[], f32[4,64])) -> (s32[], f32[4,64]) {
  %p = (s32[], f32[4,64]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %g1 = f32[4,64]{1,0} get-tuple-element(%p), index=1
  %dot.a = f32[4,64]{1,0} dot(%g1, %g1), lhs_contracting_dims={1}, rhs_contracting_dims={1}, metadata={op_name="jit(f)/bhqd,bhkd->bhqk/dot_general"}
  %one = s32[] constant(1)
  %nx = s32[] add(%g0, %one)
  ROOT %tp = (s32[], f32[4,64]) tuple(%nx, %dot.a)
}

%attncond (p.1: (s32[], f32[4,64])) -> pred[] {
  %p.1 = (s32[], f32[4,64]) parameter(0)
  %g2 = s32[] get-tuple-element(%p.1), index=0
  %c4 = s32[] constant(4)
  ROOT %lt = pred[] compare(%g2, %c4), direction=LT
}

ENTRY %main (x: f32[4,64]) -> f32[4,64] {
  %x = f32[4,64]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %t0 = (s32[], f32[4,64]) tuple(%c0, %x)
  %w = (s32[], f32[4,64]) while(%t0), condition=%attncond, body=%attnbody, backend_config={"known_trip_count":{"n":"4"}}
  %big = f32[1024,1024]{1,0} broadcast(%c0), dimensions={}
  %red = f32[] reduce(%big, %c0), dimensions={0,1}, to_apply=%attncond
  ROOT %o = f32[4,64]{1,0} get-tuple-element(%w), index=1
}
"""


def test_attention_attribution_subset_of_total():
    total = analyze(HLO).bytes
    attn = attention_scan_bytes(HLO)
    assert 0 < attn <= total
    # the 4 MiB broadcast+reduce outside the attention while is NOT
    # attributed to attention
    assert total - attn >= 1024 * 1024 * 4
